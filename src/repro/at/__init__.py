"""``repro.at`` — the public auto-tuning API (one frontend, paper-faithful).

The paper's single ``!OAT$`` directive surface, reproduced as one session
object instead of four parallel frontends:

* :class:`AutoTuner` — the session: declare regions with the
  :meth:`~repro.at.session.AutoTuner.autotune` decorator (or the comment
  DSL via :meth:`~repro.at.session.AutoTuner.preprocess`), run phases with
  :meth:`~repro.at.session.AutoTuner.run`, invoke regions with
  :meth:`~repro.at.session.AutoTuner.execute`.
* :func:`tuned` — what kernels call to pick up tuned PPs (replaces the
  ``ops.set_tuned`` side-channel).
* :data:`searchers` / :data:`executors` / :data:`record_backends` —
  pluggable backend registries; new strategies and storage layers
  register by name instead of editing the runtime.
* :class:`ATRecordStore` — the persistent tuning database (JSON-lines
  under the workdir, keyed by machine fingerprint + region + BP point);
  install/static optima survive process restarts and are warm-loaded
  without re-timing.  :class:`SqliteRecordStore` is the transactional
  fleet-grade alternative, and :func:`open_record_store` overlays either
  on a read-only **golden** winner DB (``python -m repro.at export`` /
  ``merge`` / ``promote`` move winners between deployments).

Phase constants (``INSTALL``/``STATIC``/``DYNAMIC``/``ALL``) and the
declaration vocabulary (:class:`Varied`, :class:`Fitting`,
:class:`According`, :class:`ParamDecl`) are re-exported so application
code needs no ``repro.core`` imports.
"""
from ..core.cost import According
from ..core.params import ParamDecl, Varied
from ..core.region import ATRegion, Fitting
from ..core.runtime import (OAT_ALL, OAT_DYNAMIC, OAT_INSTALL, OAT_STATIC)
from .backends import BackendRegistry, executors, searchers
from .records import (ATRecordStore, ATRecordWarning, GoldenOverlayStore,
                      GoldenStore, RecordBackend, TuningRecord,
                      machine_fingerprint, open_record_store,
                      read_records_file, record_backends,
                      reset_fingerprint_cache, write_records_file)
from .sqlite_backend import SqliteRecordStore
from .session import (AutoTuner, SelectHandle, TunedRegion, clear_published,
                      current_session, publish, publish_for_bp, tuned,
                      use_session)

# friendlier aliases for the paper's §6.1 constants
ALL = OAT_ALL
INSTALL = OAT_INSTALL
STATIC = OAT_STATIC
DYNAMIC = OAT_DYNAMIC


def autotune(*args, **kwargs):
    """Module-level :meth:`AutoTuner.autotune` against the current session
    (creating a default session in the cwd if none is active)."""
    session = current_session() or AutoTuner()
    return session.autotune(*args, **kwargs)


__all__ = [
    "ALL", "INSTALL", "STATIC", "DYNAMIC",
    "OAT_ALL", "OAT_INSTALL", "OAT_STATIC", "OAT_DYNAMIC",
    "ATRecordStore", "ATRecordWarning", "ATRegion", "According",
    "AutoTuner", "BackendRegistry", "Fitting", "GoldenOverlayStore",
    "GoldenStore", "ParamDecl", "RecordBackend", "SelectHandle",
    "SqliteRecordStore", "TunedRegion", "TuningRecord", "Varied",
    "autotune", "clear_published", "current_session", "executors",
    "machine_fingerprint", "open_record_store", "publish",
    "publish_for_bp", "read_records_file", "record_backends",
    "reset_fingerprint_cache", "searchers", "tuned", "use_session",
    "write_records_file",
]
