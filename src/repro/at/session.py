"""The ``AutoTuner`` session — one coherent frontend over the FIBER runtime.

This subsumes the four historical frontends (the ``#OAT$`` comment DSL, the
``install_unroll``-family decorators, the ``SelectRegion`` builder, and raw
``OAT_ATexec`` calls) behind a single object:

    import repro.at as at

    tuner = at.AutoTuner(workdir)
    tuner.set_bps(numprocs=1, start=1024, end=4096, dist=1024)

    @tuner.autotune("install", "variable", name="MatmulBlocks",
                    varied=at.Varied(("bm", "bn"), values=(128, 256, 512)),
                    search="ad-hoc", publish=("matmul", {"bm": "block_m",
                                                         "bn": "block_n"}))
    def matmul_blocks(bm=128, bn=128):
        ...

    sel = tuner.autotune("dynamic", "select", name="DecodeBucket_512")
    sel.alternative(name="bk=256")(decode_256)

    tuner.run("install")            # warm-loads from the ATRecordStore,
                                    # tunes only what has no record
    at.tuned("matmul")              # {'block_m': 256, 'block_n': 128}

Kernels call :func:`tuned` instead of importing ``ops.set_tuned``
side-channels; tuned optima persist across processes through the
:class:`~repro.at.records.ATRecordStore` (install/static results are
re-loaded without re-timing — zero executor invocations on the warm path).
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

from ..core import paramfile
from ..core.cost import According
from ..core.directives import _coerce_params, region as _region_decorator
from ..core.errors import OATSpecError
from ..core.params import (DEFAULT_BASIC_PARAMS, OAT_ENDTUNESIZE,
                           OAT_NUMPROCS, OAT_SAMPDIST, OAT_STARTTUNESIZE)
from ..core.region import ATRegion, Subregion
from ..core.runtime import (OAT_ALL, OAT_DYNAMIC, OAT_INSTALL, OAT_PROBSIZE,
                            OAT_STATIC, ATContext)
from ..core.search import SearchPlan
from .backends import executors, searchers
from .records import ATRecordStore, bp_key, open_record_store

PHASE_ORDER = ("install", "static", "dynamic")
_PHASE_KIND = {"install": OAT_INSTALL, "static": OAT_STATIC,
               "dynamic": OAT_DYNAMIC}

_BP_ALIASES = {
    "numprocs": OAT_NUMPROCS, "start": OAT_STARTTUNESIZE,
    "end": OAT_ENDTUNESIZE, "dist": OAT_SAMPDIST,
}

# --------------------------------------------------------------------------
# published kernel PPs — the lookup the kernel layer reads (replaces the
# ops.set_tuned side-channel; ops.set_tuned is now a shim over publish())
# --------------------------------------------------------------------------

_published: dict[str, dict[str, Any]] = {}
_published_bp: dict[tuple, dict[str, Any]] = {}   # (kernel, bp_key) -> pps


def publish(kernel: str, **pps: Any) -> None:
    """Record tuned PPs for a kernel (machine-global within the process)."""
    _published.setdefault(kernel, {}).update(pps)


def publish_for_bp(kernel: str, bp: dict[str, Any], **pps: Any) -> None:
    _published_bp.setdefault((kernel, bp_key(bp)), {}).update(pps)


def tuned(kernel: str, **bps: Any) -> dict[str, Any]:
    """Tuned PPs for ``kernel``; a BP point selects per-size static optima.

    ``tuned("matmul")`` returns install-time (machine-scoped) optima;
    ``tuned("matmul", OAT_PROBSIZE=2048)`` overlays any static optimum
    recorded for that exact BP point.
    """
    out = dict(_published.get(kernel, {}))
    if bps:
        out.update(_published_bp.get((kernel, bp_key(bps)), {}))
    return out


def clear_published() -> None:
    """Reset the published-PP tables (test isolation)."""
    _published.clear()
    _published_bp.clear()


# --------------------------------------------------------------------------
# session-level handles
# --------------------------------------------------------------------------

_current: "AutoTuner | None" = None


def current_session() -> "AutoTuner | None":
    return _current


def use_session(session: "AutoTuner | None") -> "AutoTuner | None":
    global _current
    prev, _current = _current, session
    return prev


class TunedRegion:
    """Handle returned by :meth:`AutoTuner.autotune` for non-select regions.

    Callable — invoking it executes the region through the runtime with the
    currently-committed PPs (run-time AT happens here for dynamic regions).
    """

    def __init__(self, session: "AutoTuner", region: ATRegion):
        self.session = session
        self.region = region

    @property
    def name(self) -> str:
        return self.region.name

    def __call__(self, *args, **kwargs) -> Any:
        return self.session.execute(self.region.name, *args, **kwargs)

    def best(self) -> dict[str, Any]:
        return self.session.best(self.region.name)


class SelectHandle:
    """Builder for ``select`` regions under a session.

    Unlike the legacy ``SelectRegion``, the region registers immediately —
    there is no ``finalize`` step to forget (it remains as a no-op for
    migration ease).  Alternatives append via the ``alternative`` decorator.
    """

    def __init__(self, session: "AutoTuner", phase: str, name: str, *,
                 params: Sequence = (), according=None, search=None,
                 number=None, parent: ATRegion | None = None,
                 metadata: dict | None = None):
        self.session = session
        if isinstance(according, str):
            according = According.parse(according)
        self.region = ATRegion(
            at_type=phase, feature="select", name=name,
            params=_coerce_params(params), according=according,
            search=search, number=number, metadata=metadata or {})
        if parent is not None:
            parent.add_child(self.region)
            session.ctx.registry.register(self.region)
        else:
            session.ctx.register(self.region)

    @property
    def name(self) -> str:
        return self.region.name

    def alternative(self, according=None, name: str = "") -> Callable:
        if isinstance(according, str):
            according = According.parse(according)

        def deco(fn: Callable) -> Callable:
            self.region.subregions.append(
                Subregion(fn=fn, according=according,
                          name=name or fn.__name__))
            return fn
        return deco

    def finalize(self) -> ATRegion:
        return self.region          # compat no-op: already registered

    def __call__(self, *args, **kwargs) -> Any:
        return self.session.execute(self.region.name, *args, **kwargs)


# --------------------------------------------------------------------------
# the session object
# --------------------------------------------------------------------------

class AutoTuner:
    """One auto-tuning session: context + parameter store + record DB.

    Parameters
    ----------
    workdir:
        Where parameter files and the tuning database live.
    ctx:
        Adopt an existing :class:`ATContext` instead of creating one
        (migration path for callers holding a raw context).
    machine:
        Override the machine fingerprint records are keyed by.
    executor:
        Default executor backend name (``at.executors``) for regions that
        do not select one via ``autotune(..., executor=...)``.
    searcher:
        Optional searcher backend name (``at.searchers``); ``None`` keeps
        the paper's per-region method composition.
    record_backend:
        Tuning-DB storage backend name (``at.record_backends``):
        ``"jsonl"`` (default) or ``"sqlite"``.
    golden_db:
        Path to a read-only golden winner DB (exported via ``python -m
        repro.at export``/``promote``) overlaid under the local store:
        local record beats golden, golden beats cold — a fresh workdir
        pointed at a golden DB warm-loads with zero measurements.
    """

    def __init__(self, workdir: str = ".", *, ctx: ATContext | None = None,
                 machine: str | None = None, feedback: bool = False,
                 executor: str = "wall-clock", searcher: str | None = None,
                 records: ATRecordStore | None = None,
                 record_backend: str = "jsonl",
                 golden_db: str | None = None):
        self.ctx = ctx or ATContext(workdir, feedback=feedback)
        self.workdir = self.ctx.workdir
        self.records = records or open_record_store(
            self.workdir, backend=record_backend, machine=machine,
            golden_db=golden_db)
        self.executor = executor
        self.executor_calls = 0
        self.warm_hits: list[tuple[str, str]] = []    # (phase, region)
        self._publish_maps: dict[str, tuple[str, dict]] = {}
        self._dynamic_persisted: set[str] = set()
        # adopting a context that already carries a caller-supplied
        # executor factory (the pre-session API) keeps it: that factory
        # measures every region, as it did before the session existed
        prior = self.ctx._executor_factory
        self._adopted_factory = None if getattr(
            prior, "__func__", None) is ATContext._default_executor else prior
        self.ctx._executor_factory = self._executor_factory
        if searcher is not None:
            self.ctx.searcher = searchers.get(searcher)
        self.ctx._at_session = self
        use_session(self)

    @classmethod
    def for_context(cls, ctx: "ATContext | AutoTuner") -> "AutoTuner":
        """The session owning ``ctx`` (created and cached on first use)."""
        if isinstance(ctx, AutoTuner):
            return ctx
        existing = getattr(ctx, "_at_session", None)
        if existing is not None:
            return existing
        return cls(ctx=ctx)

    # ------------------------------------------------------------------
    # basic parameters
    # ------------------------------------------------------------------
    def set_bps(self, **bps: Any) -> "AutoTuner":
        """Set basic parameters; lowercase aliases map to the OAT names
        (``numprocs``/``start``/``end``/``dist``)."""
        for k, v in bps.items():
            self.ctx.store.set_bp(_BP_ALIASES.get(k, k), v)
        return self

    def ensure_default_bps(self, numprocs: int = 1, start: int = 1024,
                           end: int = 4096, dist: int = 1024) -> "AutoTuner":
        if not self.ctx.store.has_default_bps():
            self.set_bps(numprocs=numprocs, start=start, end=end, dist=dist)
        return self

    # ------------------------------------------------------------------
    # declaration — the one decorator
    # ------------------------------------------------------------------
    def autotune(self, phase: str = "install", feature: str = "variable", *,
                 name: str | None = None, varied=None, fitting=None,
                 params: Sequence = (), according=None, search=None,
                 number=None, executor: str | Callable | None = None,
                 cost=None, publish: tuple[str, dict] | None = None,
                 prepro=None, postpro=None, debug: tuple = (),
                 parent: ATRegion | None = None, metadata: dict | None = None):
        """Declare a tuning region (all four legacy frontends in one).

        * ``feature='variable' | 'unroll' | 'define'`` — returns a decorator
          for a variant generator; the decorated object is a callable
          :class:`TunedRegion` handle.
        * ``feature='select'`` — returns a :class:`SelectHandle` builder
          (``.alternative`` decorator; no ``finalize`` needed).
        * ``publish=(kernel, {pp: kernel_kwarg})`` wires tuned values into
          :func:`tuned` for the kernel layer (PP keys may be bare ``varied``
          names or qualified ``Region_PP`` names).
        * ``executor`` / ``cost`` select the measurement backend for this
          region (``at.executors`` name, or an inline cost model).
        """
        md = dict(metadata or {})
        if executor is not None:
            md["executor"] = executor
        if cost is not None:
            md["cost"] = cost
        if feature == "select":
            if name is None:
                raise OATSpecError("select regions require a name")
            handle = SelectHandle(self, phase, name, params=params,
                                  according=according, search=search,
                                  number=number, parent=parent, metadata=md)
            if publish is not None:
                self._publish_maps[name] = publish
            return handle

        def deco(fn: Callable) -> TunedRegion:
            r = _region_decorator(
                self.ctx, phase, feature, name or fn.__name__,
                varied=varied, fitting=fitting, params=params,
                according=according, search=search, number=number,
                prepro=prepro, postpro=postpro, debug=debug, parent=parent,
                metadata=md)(fn)
            if publish is not None:
                self._publish_maps[r.name] = publish
            return TunedRegion(self, r)
        return deco

    def preprocess(self, fn: Callable, outdir: str | None = None
                   ) -> dict[str, ATRegion]:
        """The comment-DSL path: expand ``#OAT$`` directives in ``fn`` via
        OATCodeGen and register the resulting regions with this session."""
        from ..core.dsl import preprocess as _preprocess
        return _preprocess(fn, self.ctx, outdir)

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------
    def _executor_factory(self, region: ATRegion, bp_env: dict
                          ) -> Callable[[dict], float]:
        if self._adopted_factory is not None:
            factory = self._adopted_factory
        else:
            backend = region.metadata.get("executor") or self.executor
            factory = executors.get(backend) if isinstance(backend, str) \
                else backend
        inner = factory(region, bp_env)

        def measure(assignment: dict) -> float:
            self.executor_calls += 1
            return inner(assignment)
        return measure

    def run(self, phase: str | int = "all",
            routines: Sequence[str] | None = None,
            force: bool = False) -> dict[str, list[str]]:
        """Run one or all tuning phases, warm-loading persisted optima.

        For each region: if the :class:`ATRecordStore` holds a record for
        this machine + region + BP point (all grid points, for static), the
        optimum is applied without invoking any executor; otherwise the
        region is tuned through ``OAT_ATexec`` and the result persisted.
        ``force=True`` re-tunes everything.  Returns ``{phase: tuned
        region names}`` (warm loads excluded — see :attr:`warm_hits`).
        """
        if phase in (OAT_ALL, "all"):
            phases: tuple[str, ...] = PHASE_ORDER
        elif phase in _PHASE_KIND:
            phases = (str(phase),)
        elif phase in (OAT_INSTALL, OAT_STATIC, OAT_DYNAMIC):
            phases = ({v: k for k, v in _PHASE_KIND.items()}[phase],)
        else:
            raise OATSpecError(f"unknown phase {phase!r}")
        ran: dict[str, list[str]] = {}
        for ph in phases:
            names = list(routines) if routines is not None \
                else list(self.ctx.routines[ph])
            if ph == "dynamic":
                if names:
                    self.ctx.OAT_ATexec(OAT_DYNAMIC, names)
                    if not force:
                        self._warm_dynamic(names)
                ran[ph] = names
                continue
            warm: list[tuple[str, Any]] = []
            cold: list[str] = []
            for n in names:
                rec = None if force else self._warm_lookup(ph, n)
                if rec is not None:
                    warm.append((n, rec))
                else:
                    cold.append(n)
            if warm:
                self._apply_warm(ph, warm)
            if cold:
                self.ctx.OAT_ATexec(_PHASE_KIND[ph], cold)
                self._persist_phase(ph, cold)
            elif names:
                self.ctx.phase_ran[ph] = True
            for n in names:
                self._publish_region(self.ctx.registry.get(n))
            ran[ph] = cold
        return ran

    def execute(self, name: str, *args, **kwargs) -> Any:
        """Invoke a region (run-time AT happens here for dynamic regions);
        newly-committed dynamic winners are persisted to the record store."""
        out = self.ctx.execute(name, *args, **kwargs)
        st = self.ctx.dynamic_state.get(name)
        if st is not None and st.committed is not None \
                and name not in self._dynamic_persisted:
            region = self.ctx.registry.get(name)
            pp_name = region.pp_names[0] if region.pp_names \
                else f"{name}_SELECT"
            # OAT_NUMALT stamps the record with the size of the variant
            # space the winner index is valid against: a later session
            # whose region has grown (e.g. a new num_splits axis) must
            # re-measure instead of committing a stale index
            self.records.put("dynamic", name, {},
                             {pp_name: st.committed,
                              "OAT_NUMALT": len(region.subregions)},
                             cost=st.tried.get(st.committed))
            self._dynamic_persisted.add(name)
            self._publish_region(region)
        return out

    def best(self, region_name: str) -> dict[str, Any]:
        """The tuned PP assignment currently committed for a region."""
        region = self.ctx.registry.get(region_name)
        out: dict[str, Any] = {}
        for pp in self._pp_names(region):
            e = self.ctx.store.entry(pp)
            if e is not None:
                out[pp] = e.value
        return out

    def static_pp(self, region_name: str, pp: str, probsize: int) -> Any:
        """Static-tuned PP at an arbitrary problem size (CDF-interpolated)."""
        return self.ctx.static_pp(region_name, pp, probsize)

    # ------------------------------------------------------------------
    # warm path / persistence
    # ------------------------------------------------------------------
    @staticmethod
    def _pp_names(region: ATRegion) -> list[str]:
        if region.feature == "define":
            return [p.name for p in region.params if p.attr == "out"]
        try:
            return [a.name for a in SearchPlan(region).all_axes]
        except OATSpecError:
            return []

    def _warm_lookup(self, phase: str, name: str):
        if phase == "install":
            rec = self.records.lookup("install", name, {})
            return rec if rec is not None and rec.pp else None
        # static: every BP grid point must be recorded
        try:
            grid = self.ctx._bp_grid()
        except Exception:
            return None
        out = []
        for bp_env in grid:
            rec = self.records.lookup("static", name, bp_env)
            if rec is None or not rec.pp:
                return None
            out.append((bp_env, rec))
        return out

    def _apply_warm(self, phase: str, warm: list[tuple[str, Any]]) -> None:
        if phase == "install":
            path = paramfile.param_path(self.workdir, "install")
            nodes = {n.name: n for n in paramfile.load_file(path)}
            for name, rec in warm:
                node = paramfile.Node(name)
                for k, v in rec.pp.items():
                    self.ctx.store.set_pp(k, v, "install")
                    node.set(k, v)
                nodes[name] = node
                self.warm_hits.append(("install", name))
            paramfile.save_file(path, list(nodes.values()))
        else:
            path = paramfile.param_path(self.workdir, "static")
            nodes = {n.name: n for n in paramfile.load_file(path)}
            header = paramfile.Node("BasicParam")
            for k in DEFAULT_BASIC_PARAMS:
                if self.ctx.store.get_bp(k) is not None:
                    header.set(k, self.ctx.store.get_bp(k))
            nodes["BasicParam"] = header
            for name, recs in warm:
                node = paramfile.Node(name)
                node.set(OAT_NUMPROCS, self.ctx.store.get_bp(OAT_NUMPROCS))
                node.set(OAT_SAMPDIST, self.ctx.store.get_bp(OAT_SAMPDIST))
                for bp_env, rec in recs:
                    group = paramfile.Node(OAT_PROBSIZE,
                                           bp_env[OAT_PROBSIZE])
                    for k, v in bp_env.items():
                        if k != OAT_PROBSIZE:
                            group.set(k, v)
                    key = bp_key(bp_env)
                    for k, v in rec.pp.items():
                        group.set(k, v)
                        self.ctx.store.set_pp(f"{k}@{key}", v, "static")
                        self.ctx.store.set_pp(k, v, "static")
                    node.children.append(group)
                nodes[name] = node
                self.warm_hits.append(("static", name))
            paramfile.save_file(path, list(nodes.values()))
        self.ctx.phase_ran[phase] = True

    def _persist_phase(self, phase: str, names: list[str]) -> None:
        path = paramfile.param_path(self.workdir, phase)
        nodes = {n.name: n for n in paramfile.load_file(path)}
        for name in names:
            node = nodes.get(name)
            if node is None:
                continue
            region = self.ctx.registry.get(name)
            n_evals = self.ctx.search_log.get(name)
            if phase == "install":
                pp = {c.name: c.value for c in node.children
                      if not c.children and c.value is not None}
                if pp:
                    self.records.put("install", name, {}, pp,
                                     n_evaluations=n_evals)
                continue
            pp_names = set(self._pp_names(region))
            for group in node.children:
                if group.name != OAT_PROBSIZE:
                    continue
                bp = {OAT_PROBSIZE: group.value}
                pp = {}
                for c in group.children:
                    (pp if c.name in pp_names else bp)[c.name] = c.value
                if pp:
                    self.records.put("static", name, bp, pp,
                                     n_evaluations=n_evals)

    def _warm_dynamic(self, names: list[str]) -> None:
        for name in names:
            rec = self.records.lookup("dynamic", name, {})
            if rec is None or not rec.pp:
                continue
            region = self.ctx.registry.get(name)
            st = self.ctx.dynamic_state.get(name)
            if st is None or st.committed is not None:
                continue
            pp = dict(rec.pp)
            n_alt = pp.pop("OAT_NUMALT", None)
            if not pp:
                continue
            if n_alt is not None and int(n_alt) != len(region.subregions):
                # the variant space grew/shrank since this winner was
                # recorded (its index means something else now): fall
                # through to a cold re-measure of just this region
                continue
            pp_name, idx = next(iter(pp.items()))
            st.committed = int(idx)
            self.ctx.store.set_pp(pp_name, int(idx), "dynamic")
            self._dynamic_persisted.add(name)
            self.warm_hits.append(("dynamic", name))
            self._publish_region(region)

    # ------------------------------------------------------------------
    # publishing into the kernel-layer lookup
    # ------------------------------------------------------------------
    def _publish_region(self, region: ATRegion) -> None:
        spec = self._publish_maps.get(region.name)
        if spec is None:
            return
        kernel, mapping = spec
        vals: dict[str, Any] = {}
        for src, dst in mapping.items():
            e = self.ctx.store.entry(src) \
                or self.ctx.store.entry(f"{region.name}_{src.upper()}")
            if e is not None:
                vals[dst] = e.value
        if vals:
            publish(kernel, **vals)
        if region.at_type == "static":
            for rec in self.records.lookup_all("static", region.name):
                per_bp: dict[str, Any] = {}
                for src, dst in mapping.items():
                    qual = f"{region.name}_{src.upper()}"
                    if src in rec.pp:
                        per_bp[dst] = rec.pp[src]
                    elif qual in rec.pp:
                        per_bp[dst] = rec.pp[qual]
                if per_bp:
                    publish_for_bp(kernel, rec.bp, **per_bp)
