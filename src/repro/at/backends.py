"""Pluggable search and measurement backends for the ``repro.at`` session.

Two registries, mirroring the paper's two orthogonal axes of tuning:

* :data:`searchers` — how the PP space is traversed.  Entries take a
  compiled :class:`~repro.core.search.SearchPlan` and a ``measure``
  callable and return a :class:`~repro.core.search.SearchResult`.
* :data:`executors` — how one PP assignment is costed.  Entries are
  factories ``(region, bp_env) -> measure(assignment) -> cost``.

New strategies register by name (``@searchers.register("my-search")``)
instead of editing ``core/runtime.py``; an :class:`AutoTuner` selects them
by name per session or per region (``autotune(..., executor="interp")``).

Built-ins:

========  =============================================================
searcher  semantics
========  =============================================================
composed  paper §6.4.2 per-region composition (SearchPlan.run; default)
brute-force  one joint Cartesian product over *all* axes
ad-hoc    coordinate descent over all axes, innermost scalar first
dspline-guided  coordinate pass measuring only d-Spline sample points
          per axis; the optimum over the full range is inferred (§3.4.3)
========  =============================================================

========  =============================================================
executor  semantics
========  =============================================================
wall-clock  times the region's variant callable (JAX-aware blocking)
analytic-cost  no execution: evaluates ``metadata['cost']`` (expression
          or callable) over BPs + the assignment; if absent, calls the
          variant generator and uses its returned float as the cost
interp    registered by ``tuning/install.py`` — interpret-mode Pallas
          wall-clock on CPU (small shapes)
========  =============================================================
"""
from __future__ import annotations

import itertools
from typing import Any, Callable

from ..core.errors import OATSpecError
from ..core.executor import CostModelExecutor, WallClockExecutor
from ..core.fitting import auto_sample_points, fit_dspline
from ..core.search import SearchPlan, SearchResult


class BackendRegistry:
    """Name -> backend mapping with a decorator-style ``register``."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict[str, Any] = {}

    def register(self, name: str, obj: Any = None, *,
                 overwrite: bool = False):
        """Register ``obj`` under ``name``; usable as a decorator."""
        def do(o):
            if name in self._entries and not overwrite:
                raise OATSpecError(
                    f"{self.kind} backend {name!r} is already registered "
                    f"(pass overwrite=True to replace it)")
            self._entries[name] = o
            return o
        return do if obj is None else do(obj)

    def get(self, name: str):
        if name not in self._entries:
            raise OATSpecError(
                f"unknown {self.kind} backend {name!r}; registered: "
                f"{sorted(self._entries)}")
        return self._entries[name]

    def names(self) -> list[str]:
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries


searchers = BackendRegistry("searcher")
executors = BackendRegistry("executor")


# --------------------------------------------------------------------------
# built-in searchers
# --------------------------------------------------------------------------

@searchers.register("composed")
def composed_search(plan: SearchPlan, measure: Callable[[dict], float],
                    init: dict | None = None) -> SearchResult:
    """The paper's per-region method composition (§6.4.2) — the default."""
    return plan.run(measure, init=init)


@searchers.register("brute-force")
def brute_force_search(plan: SearchPlan, measure: Callable[[dict], float],
                       init: dict | None = None) -> SearchResult:
    """Joint exhaustive product over every axis of the region tree.

    Pinned axes (``init`` — user Def-file collisions, §6.3) are held
    fixed, not enumerated.
    """
    history: list[tuple[dict, float]] = []
    pinned = dict(init or {})
    free = [a for a in plan.all_axes if a.name not in pinned]
    names = [a.name for a in free]
    best, best_cost = None, float("inf")
    for combo in itertools.product(*[a.candidates for a in free]):
        asg = dict(pinned)
        asg.update(zip(names, combo))
        c = float(measure(dict(asg)))
        history.append((dict(asg), c))
        if c < best_cost:
            best, best_cost = asg, c
    return SearchResult(best, best_cost, len(history), history)


@searchers.register("ad-hoc")
def ad_hoc_search(plan: SearchPlan, measure: Callable[[dict], float],
                  init: dict | None = None) -> SearchResult:
    """Coordinate descent over all axes, innermost scalar first."""
    return _coordinate_search(plan, measure, init, guided=False)


@searchers.register("dspline-guided")
def dspline_guided_search(plan: SearchPlan, measure: Callable[[dict], float],
                          init: dict | None = None) -> SearchResult:
    """Coordinate pass measuring only d-Spline sample points per axis.

    For each numeric axis with enough candidates, only the paper's
    ``auto`` sample points are measured; the optimum over the full
    candidate range is inferred from the fitted d-Spline (§3.4.3).
    """
    return _coordinate_search(plan, measure, init, guided=True)


def _coordinate_search(plan: SearchPlan, measure, init, *,
                       guided: bool) -> SearchResult:
    history: list[tuple[dict, float]] = []

    def ev(asg: dict) -> float:
        c = float(measure(dict(asg)))
        history.append((dict(asg), c))
        return c

    current = {a.name: a.candidates[0] for a in plan.all_axes}
    if init:
        current.update({k: v for k, v in init.items() if k in current})
    fitted: dict[str, bool] = {}
    for a in reversed(plan.all_axes):
        pts = list(a.measured_points())
        numeric = all(isinstance(v, (int, float)) and not isinstance(v, bool)
                      for v in a.candidates)
        if guided and a.sampled is None and numeric and len(a.candidates) >= 5:
            samples = [v for v in auto_sample_points(
                min(a.candidates), max(a.candidates)) if v in a.candidates]
            if len(samples) >= 4:
                pts = samples
        costs = []
        for v in pts:
            asg = dict(current)
            asg[a.name] = v
            costs.append(ev(asg))
        if len(pts) < len(a.candidates) and numeric:
            pred = fit_dspline([float(p) for p in pts], costs)
            import numpy as np

            grid = np.asarray([float(c) for c in a.candidates])
            current[a.name] = a.candidates[int(np.argmin(pred(grid)))]
            fitted[a.name] = True
        else:
            current[a.name] = pts[min(range(len(costs)),
                                      key=costs.__getitem__)]
    final_cost = min((c for asg, c in history
                      if all(asg.get(k) == v for k, v in current.items())),
                     default=min(c for _, c in history))
    return SearchResult(dict(current), final_cost, len(history), history,
                        fitted)


# --------------------------------------------------------------------------
# built-in executors
# --------------------------------------------------------------------------

def variant_kwargs(region, assignment: dict, bp_env: dict) -> dict:
    """Bare kwargs for a region's variant generator from a PP assignment."""
    out: dict = {}
    for r in region.flatten():
        if r.varied is None:
            continue
        for bare, pp in zip(r.varied.names, r.pp_names):
            if pp in assignment:
                out[bare] = assignment[pp]
    out.update({k: v for k, v in bp_env.items() if k in region.bp_names})
    return out


@executors.register("wall-clock")
def wall_clock_executor(region, bp_env: dict) -> Callable[[dict], float]:
    """Time the variant callable (the paper's measurement semantics)."""
    def make_variant(assignment: dict) -> Callable[[], Any]:
        kwargs = variant_kwargs(region, assignment, bp_env)
        return lambda: region.fn(**kwargs)
    return WallClockExecutor(make_variant, repeats=1, warmup=0)


@executors.register("analytic-cost")
def analytic_cost_executor(region, bp_env: dict) -> Callable[[dict], float]:
    """Cost without execution (``according estimated`` generalised).

    Uses ``region.metadata['cost']`` (expression string or callable over
    BPs + the assignment) when present; otherwise the variant generator
    itself is treated as the cost model — it is called and its returned
    value (or the value returned by the callable it produces) is the cost.
    """
    cost = region.metadata.get("cost")
    if cost is not None:
        return CostModelExecutor(cost, env=dict(bp_env))

    def measure(assignment: dict) -> float:
        out = region.fn(**variant_kwargs(region, assignment, bp_env))
        if callable(out):
            out = out()
        return float(out)
    return measure
