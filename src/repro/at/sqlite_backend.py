"""sqlite tuning-DB backend — one transactional file, concurrency-safe.

The JSONL default is append-only and fine for one writer per line; this
backend is for the fleet shape (ROADMAP item 3): many serve/bench workers
sharing one tuning DB, where every ``put`` must be a transaction and a
key must hold exactly one row (no replay-the-log semantics).  A golden DB
exported with a ``.sqlite`` extension uses the same schema, so a shipped
winner file is directly openable by this backend.

Stdlib ``sqlite3`` only; a connection is opened per operation so forked
workers never share one handle, and the 30 s busy timeout rides out
concurrent writers' transactions.
"""
from __future__ import annotations

import json
import os
import sqlite3

from .records import (RecordBackend, TuningRecord, _sanitize_loaded, bp_key,
                      record_backends)

SQLITE_FILENAME = "OAT_Records.sqlite"

_SCHEMA = """
CREATE TABLE IF NOT EXISTS records (
    machine       TEXT NOT NULL,
    phase         TEXT NOT NULL,
    region        TEXT NOT NULL,
    bp_key        TEXT NOT NULL,
    bp            TEXT NOT NULL,
    pp            TEXT NOT NULL,
    cost          REAL,
    n_evaluations INTEGER,
    PRIMARY KEY (machine, phase, region, bp_key)
)
"""


@record_backends.register("sqlite")
class SqliteRecordStore(RecordBackend):
    """Transactional single-file tuning DB (``OAT_Records.sqlite``).

    Same store API and in-memory indexes as the JSONL backend; on disk a
    key is upserted in place (``INSERT OR REPLACE`` inside a
    transaction), so concurrent workers see whole records or nothing —
    there is no torn-line failure mode to recover from.
    """

    backend_name = "sqlite"

    def __init__(self, workdir: str = ".", machine: str | None = None,
                 path: str | None = None):
        self.path = path or os.path.join(workdir, SQLITE_FILENAME)
        super().__init__(workdir, machine=machine)

    def _connect(self) -> sqlite3.Connection:
        parent = os.path.dirname(self.path)
        os.makedirs(parent or ".", exist_ok=True)
        con = sqlite3.connect(self.path, timeout=30.0)
        con.execute(_SCHEMA)
        return con

    def _load(self) -> None:
        if not os.path.exists(self.path):
            return
        con = self._connect()
        try:
            rows = con.execute(
                "SELECT machine, phase, region, bp, pp, cost, "
                "n_evaluations FROM records").fetchall()
        finally:
            con.close()
        for machine, phase, region, bp, pp, cost, n_evals in rows:
            self._remember(TuningRecord(**_sanitize_loaded({
                "machine": machine, "phase": phase, "region": region,
                "bp": json.loads(bp), "pp": json.loads(pp),
                "cost": cost, "n_evaluations": n_evals})))

    def _append(self, rec: TuningRecord) -> None:
        con = self._connect()
        try:
            with con:
                con.execute(
                    "INSERT OR REPLACE INTO records VALUES "
                    "(?, ?, ?, ?, ?, ?, ?, ?)",
                    (rec.machine, rec.phase, rec.region,
                     json.dumps(bp_key(rec.bp)), json.dumps(rec.bp),
                     json.dumps(rec.pp), rec.cost, rec.n_evaluations))
        finally:
            con.close()
