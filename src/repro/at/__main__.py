"""``python -m repro.at`` — see :mod:`repro.at.cli`."""
import sys

from .cli import main

sys.exit(main())
