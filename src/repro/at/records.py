"""Persistent tuning database — the ``ATRecordStore``.

The paper pays tuning cost at install/static time and amortises it over
every later run; this module makes that durable across *processes*: every
tuned optimum is appended to a JSON-lines file under the session workdir,
keyed by

    (machine fingerprint, phase, region name, canonical BP point)

so a fresh :class:`~repro.at.session.AutoTuner` pointed at the same workdir
reloads install/static optima without re-timing anything (the warm path).
The paper's human-readable ``OAT_*Param.dat`` S-expression files are still
written by the runtime for fidelity; this store is the machine-queryable
index over the same results.
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass, field
from typing import Any, Iterator

RECORDS_FILENAME = "OAT_Records.jsonl"

_fingerprint_cache: str | None = None


def machine_fingerprint() -> str:
    """A stable id for 'this machine' as the tuner sees it.

    Install-time PPs depend only on the hardware (paper §3.1), so records
    are scoped by platform + accelerator backend + device kind + host
    parallelism: a record tuned on one fingerprint is never served to
    another.
    """
    global _fingerprint_cache
    if _fingerprint_cache is not None:
        return _fingerprint_cache
    import platform

    parts = [platform.system().lower(), platform.machine()]
    try:
        import jax

        parts.append(jax.default_backend())
        devs = jax.devices()
        if devs:
            parts.append(getattr(devs[0], "device_kind", "unknown")
                         .replace(" ", "-").lower())
        parts.append(f"n{len(devs)}")
    except Exception:
        parts.append("nojax")
    _fingerprint_cache = "-".join(p for p in parts if p)
    return _fingerprint_cache


def _jsonable(v: Any) -> Any:
    """Coerce numpy scalars etc. to plain JSON types."""
    if isinstance(v, (str, bool)) or v is None:
        return v
    if isinstance(v, int):
        return v
    if isinstance(v, float):
        return v
    if hasattr(v, "item"):           # numpy scalar
        return v.item()
    return str(v)


def bp_key(bp: dict[str, Any] | None) -> tuple:
    """Canonical, hashable form of a BP point."""
    if not bp:
        return ()
    return tuple(sorted((str(k), _jsonable(v)) for k, v in bp.items()))


@dataclass
class TuningRecord:
    """One tuned optimum: the PP assignment for a (machine, region, BP)."""

    machine: str
    phase: str                        # install | static | dynamic
    region: str
    bp: dict[str, Any] = field(default_factory=dict)
    pp: dict[str, Any] = field(default_factory=dict)
    cost: float | None = None
    n_evaluations: int | None = None

    @property
    def key(self) -> tuple:
        return (self.machine, self.phase, self.region, bp_key(self.bp))


class ATRecordStore:
    """JSON-lines tuning database under ``workdir``.

    Append-only on disk (one JSON object per line; last record for a key
    wins on load), fully indexed in memory.  ``machine`` defaults to the
    live fingerprint; tests may pin it to simulate foreign machines.
    """

    def __init__(self, workdir: str = ".", machine: str | None = None):
        self.workdir = workdir
        self.machine = machine or machine_fingerprint()
        self.path = os.path.join(workdir, RECORDS_FILENAME)
        self._index: dict[tuple, TuningRecord] = {}
        self._load()

    # ------------------------------------------------------------------
    def _load(self) -> None:
        if not os.path.exists(self.path):
            return
        with open(self.path, "r") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    d = json.loads(line)
                    rec = TuningRecord(**d)
                except (json.JSONDecodeError, TypeError):
                    continue             # skip corrupt lines, keep the rest
                self._index[rec.key] = rec

    def put(self, phase: str, region: str, bp: dict[str, Any] | None,
            pp: dict[str, Any], cost: float | None = None,
            n_evaluations: int | None = None) -> TuningRecord:
        rec = TuningRecord(
            machine=self.machine, phase=phase, region=region,
            bp={str(k): _jsonable(v) for k, v in (bp or {}).items()},
            pp={str(k): _jsonable(v) for k, v in pp.items()},
            cost=None if cost is None else float(cost),
            n_evaluations=n_evaluations)
        self._index[rec.key] = rec
        os.makedirs(self.workdir or ".", exist_ok=True)
        with open(self.path, "a") as f:
            f.write(json.dumps(asdict(rec)) + "\n")
        return rec

    def lookup(self, phase: str, region: str,
               bp: dict[str, Any] | None = None) -> TuningRecord | None:
        return self._index.get((self.machine, phase, region, bp_key(bp)))

    def lookup_all(self, phase: str, region: str) -> list[TuningRecord]:
        return [r for r in self._index.values()
                if r.machine == self.machine and r.phase == phase
                and r.region == region]

    def records(self) -> Iterator[TuningRecord]:
        return iter(self._index.values())

    def regions(self, phase: str) -> list[str]:
        return sorted({r.region for r in self._index.values()
                       if r.machine == self.machine and r.phase == phase})

    def __len__(self) -> int:
        return len(self._index)

    def __contains__(self, key: tuple) -> bool:
        return key in self._index
