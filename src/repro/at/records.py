"""Persistent tuning database — pluggable record backends.

The paper pays tuning cost at install/static time and amortises it over
every later run; this module makes that durable across *processes* and
*machines*: every tuned optimum is persisted under the session workdir,
keyed by

    (machine fingerprint, phase, region name, canonical BP point)

so a fresh :class:`~repro.at.session.AutoTuner` pointed at the same workdir
reloads install/static optima without re-timing anything (the warm path).

Storage is pluggable behind the :data:`record_backends` registry (the same
shape as ``at.searchers`` / ``at.executors``):

=======  ==============================================================
backend  semantics
=======  ==============================================================
jsonl    :class:`ATRecordStore` — append-only JSON lines, one atomic
         ``O_APPEND`` write per record (concurrent serve/bench workers
         cannot interleave partial lines); the default
sqlite   :class:`~repro.at.sqlite_backend.SqliteRecordStore` — a single
         transactional file, safe under concurrent writers
memory   :class:`RecordBackend` itself — ephemeral, for tests
=======  ==============================================================

On top of any backend sits the **golden** overlay
(:class:`GoldenOverlayStore`): a read-only, fingerprint-keyed winner DB
(exported from a tuned fleet via ``python -m repro.at export`` /
``promote``) consulted whenever the local store misses — local record
beats golden, golden beats cold.  A fresh deployment pointed at a golden
DB (or seeded from one via ``repro.at merge``) warm-loads fleet-tuned
optima with zero measurements.

The paper's human-readable ``OAT_*Param.dat`` S-expression files are still
written by the runtime for fidelity; this store is the machine-queryable
index over the same results.
"""
from __future__ import annotations

import json
import math
import os
import warnings
from dataclasses import asdict, dataclass, field
from typing import Any, Iterable, Iterator

from .backends import BackendRegistry

RECORDS_FILENAME = "OAT_Records.jsonl"

_fingerprint_cache: str | None = None


class ATRecordWarning(RuntimeWarning):
    """A tuning-DB integrity problem that degraded gracefully (corrupt
    record line, missing golden DB) — never silent, never fatal."""


def machine_fingerprint() -> str:
    """A stable id for 'this machine' as the tuner sees it.

    Install-time PPs depend only on the hardware (paper §3.1), so records
    are scoped by platform + accelerator backend + device kind + host
    parallelism: a record tuned on one fingerprint is never served to
    another.

    Only the *success* path is cached: a transient jax failure (import
    error, a call before ``XLA_FLAGS`` takes effect) yields a degraded
    ``...-nojax`` fingerprint for that call alone, instead of poisoning
    every subsequent record's key for the life of the process.
    """
    global _fingerprint_cache
    if _fingerprint_cache is not None:
        return _fingerprint_cache
    import platform

    parts = [platform.system().lower(), platform.machine()]
    try:
        import jax

        parts.append(jax.default_backend())
        devs = jax.devices()
        if devs:
            parts.append(getattr(devs[0], "device_kind", "unknown")
                         .replace(" ", "-").lower())
        parts.append(f"n{len(devs)}")
    except Exception:
        # transient failure path: report, don't cache
        return "-".join(p for p in parts if p) + "-nojax"
    _fingerprint_cache = "-".join(p for p in parts if p)
    return _fingerprint_cache


def reset_fingerprint_cache() -> None:
    """Forget the cached fingerprint (tests; post-``XLA_FLAGS`` setup)."""
    global _fingerprint_cache
    _fingerprint_cache = None


def _jsonable(v: Any) -> Any:
    """Coerce numpy scalars etc. to plain, spec-valid JSON types.

    Non-finite floats become ``None``: ``json.dumps`` would otherwise
    emit ``NaN``/``Infinity`` tokens that strict parsers (sqlite, HTTP
    golden consumers) reject.
    """
    if isinstance(v, (str, bool)) or v is None:
        return v
    if isinstance(v, int):
        return v
    if isinstance(v, float):
        return v if math.isfinite(v) else None
    if hasattr(v, "item"):           # numpy scalar
        return _jsonable(v.item())
    return str(v)


def _sanitize_loaded(d: dict) -> dict:
    """Tolerate non-finite floats in records written before sanitization
    (python's json emits/accepts bare ``NaN`` tokens)."""
    c = d.get("cost")
    if isinstance(c, float) and not math.isfinite(c):
        d["cost"] = None
    for part in ("bp", "pp"):
        m = d.get(part)
        if isinstance(m, dict) and any(
                isinstance(v, float) and not math.isfinite(v)
                for v in m.values()):
            d[part] = {k: _jsonable(v) for k, v in m.items()}
    return d


def bp_key(bp: dict[str, Any] | None) -> tuple:
    """Canonical, hashable form of a BP point."""
    if not bp:
        return ()
    return tuple(sorted((str(k), _jsonable(v)) for k, v in bp.items()))


@dataclass
class TuningRecord:
    """One tuned optimum: the PP assignment for a (machine, region, BP)."""

    machine: str
    phase: str                        # install | static | dynamic
    region: str
    bp: dict[str, Any] = field(default_factory=dict)
    pp: dict[str, Any] = field(default_factory=dict)
    cost: float | None = None
    n_evaluations: int | None = None

    @property
    def key(self) -> tuple:
        return (self.machine, self.phase, self.region, bp_key(self.bp))


def prefer_incoming(cur: TuningRecord, inc: TuningRecord,
                    prefer: str = "better-cost") -> bool:
    """Merge policy for a key collision: does ``inc`` replace ``cur``?"""
    if prefer == "incoming":
        return True
    if prefer == "existing":
        return False
    if prefer != "better-cost":
        raise ValueError(f"unknown merge policy {prefer!r}")
    if inc.cost is None:
        return False
    return cur.cost is None or inc.cost < cur.cost


# --------------------------------------------------------------------------
# the backend interface (+ the in-memory reference backend)
# --------------------------------------------------------------------------

record_backends = BackendRegistry("record")


@record_backends.register("memory")
class RecordBackend:
    """Base class for tuning-DB backends — also the ``memory`` backend.

    Subclasses implement durability: :meth:`_load` repopulates the
    in-memory indexes from storage (via :meth:`_remember`) and
    :meth:`_append` persists one record.  Shared here: the primary
    ``key -> record`` index, a ``(machine, phase, region)`` secondary
    index keeping :meth:`lookup_all` / :meth:`regions` O(1) in the
    record count (the warm path hits them once per region), and the
    fleet operations (:meth:`export` / :meth:`merge_records`).
    ``machine`` defaults to the live fingerprint; tests may pin it to
    simulate foreign machines.
    """

    backend_name = "memory"

    def __init__(self, workdir: str = ".", machine: str | None = None):
        self.workdir = workdir
        self.machine = machine or machine_fingerprint()
        self._index: dict[tuple, TuningRecord] = {}
        # (machine, phase, region) -> {key: record}; replacement by key
        # stays automatic, deletion never happens (append-only store)
        self._by_region: dict[tuple, dict[tuple, TuningRecord]] = {}
        self._load()

    # -- storage hooks --------------------------------------------------
    def _load(self) -> None:
        pass

    def _append(self, rec: TuningRecord) -> None:
        pass

    # -- indexing -------------------------------------------------------
    def _remember(self, rec: TuningRecord) -> None:
        self._index[rec.key] = rec
        self._by_region.setdefault(
            (rec.machine, rec.phase, rec.region), {})[rec.key] = rec

    # -- the store API --------------------------------------------------
    def put(self, phase: str, region: str, bp: dict[str, Any] | None,
            pp: dict[str, Any], cost: float | None = None,
            n_evaluations: int | None = None) -> TuningRecord:
        rec = TuningRecord(
            machine=self.machine, phase=phase, region=region,
            bp={str(k): _jsonable(v) for k, v in (bp or {}).items()},
            pp={str(k): _jsonable(v) for k, v in pp.items()},
            cost=None if cost is None else _jsonable(float(cost)),
            n_evaluations=n_evaluations)
        return self.put_record(rec)

    def put_record(self, rec: TuningRecord) -> TuningRecord:
        """Store a fully-formed record, preserving its machine key (the
        merge path: fleet records keep the fingerprint that tuned them)."""
        self._remember(rec)
        self._append(rec)
        return rec

    def lookup(self, phase: str, region: str,
               bp: dict[str, Any] | None = None) -> TuningRecord | None:
        return self._index.get((self.machine, phase, region, bp_key(bp)))

    def lookup_all(self, phase: str, region: str) -> list[TuningRecord]:
        return list(self._by_region.get(
            (self.machine, phase, region), {}).values())

    def records(self) -> Iterator[TuningRecord]:
        return iter(self._index.values())

    def regions(self, phase: str) -> list[str]:
        return sorted({r for m, p, r in self._by_region
                       if m == self.machine and p == phase})

    def __len__(self) -> int:
        return len(self._index)

    def __contains__(self, key: tuple) -> bool:
        return key in self._index

    def describe(self) -> dict:
        """One-line identity for reports (`/v1/stats`, the serve report)."""
        return {"backend": self.backend_name,
                "path": getattr(self, "path", None),
                "machine": self.machine, "records": len(self),
                "golden": None}

    # -- fleet operations ----------------------------------------------
    def export(self, path: str, machine: str | None = None,
               phase: str | None = None) -> int:
        """Write records (all machines by default) to a golden file;
        format by extension (``.sqlite``/``.db`` → sqlite, else JSONL)."""
        recs = [r for r in self.records()
                if machine in (None, "all") or r.machine == machine]
        if phase is not None:
            recs = [r for r in recs if r.phase == phase]
        write_records_file(path, recs)
        return len(recs)

    def merge_records(self, records: Iterable[TuningRecord],
                      prefer: str = "better-cost") -> dict[str, int]:
        """Import fleet records; collisions resolve per ``prefer``
        (``better-cost`` default: lower measured cost wins)."""
        added = updated = kept = 0
        for rec in records:
            cur = self._index.get(rec.key)
            if cur is None:
                self.put_record(rec)
                added += 1
            elif prefer_incoming(cur, rec, prefer):
                self.put_record(rec)
                updated += 1
            else:
                kept += 1
        return {"added": added, "updated": updated, "kept": kept}


# --------------------------------------------------------------------------
# JSONL backend — the default
# --------------------------------------------------------------------------

@record_backends.register("jsonl")
class ATRecordStore(RecordBackend):
    """JSON-lines tuning database under ``workdir``.

    Append-only on disk (one JSON object per line; last record for a key
    wins on load), fully indexed in memory.  Each ``put`` is a single
    ``os.O_APPEND`` write, so concurrent serve/bench processes appending
    to the same file cannot interleave partial lines; a corrupt line
    (torn write from a pre-fix process, disk truncation) is skipped with
    an :class:`ATRecordWarning` naming the line, never silently.
    """

    backend_name = "jsonl"

    def __init__(self, workdir: str = ".", machine: str | None = None,
                 path: str | None = None):
        self.path = path or os.path.join(workdir, RECORDS_FILENAME)
        super().__init__(workdir, machine=machine)

    def _load(self) -> None:
        if not os.path.exists(self.path):
            return
        with open(self.path, "r") as f:
            for lineno, line in enumerate(f, 1):
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = TuningRecord(**_sanitize_loaded(json.loads(line)))
                except (json.JSONDecodeError, TypeError):
                    warnings.warn(
                        f"{self.path}:{lineno}: skipping corrupt tuning "
                        f"record (torn write?) — any winner on this line "
                        f"will re-tune", ATRecordWarning, stacklevel=2)
                    continue
                self._remember(rec)

    def _append(self, rec: TuningRecord) -> None:
        parent = os.path.dirname(self.path)
        os.makedirs(parent or ".", exist_ok=True)
        data = (json.dumps(asdict(rec), allow_nan=False) + "\n").encode()
        # one write() of one whole line: O_APPEND makes it atomic w.r.t.
        # other appenders, so records never interleave mid-line
        fd = os.open(self.path, os.O_WRONLY | os.O_APPEND | os.O_CREAT,
                     0o644)
        try:
            os.write(fd, data)
        finally:
            os.close(fd)


# --------------------------------------------------------------------------
# golden winners — read-only store + read-through overlay
# --------------------------------------------------------------------------

_SQLITE_MAGIC = b"SQLite format 3"


def read_records_file(path: str) -> list[TuningRecord]:
    """Load records from a golden DB file, sniffing sqlite vs JSONL."""
    with open(path, "rb") as f:
        magic = f.read(len(_SQLITE_MAGIC))
    if magic == _SQLITE_MAGIC:
        from .sqlite_backend import SqliteRecordStore
        return list(SqliteRecordStore(path=path).records())
    return list(ATRecordStore(os.path.dirname(path) or ".",
                              path=path).records())


def write_records_file(path: str, records: Iterable[TuningRecord]) -> None:
    """Write a golden DB file (fresh), format chosen by extension."""
    parent = os.path.dirname(path)
    os.makedirs(parent or ".", exist_ok=True)
    if path.endswith((".sqlite", ".db")):
        from .sqlite_backend import SqliteRecordStore
        if os.path.exists(path):
            os.remove(path)
        store = SqliteRecordStore(path=path)
        for rec in records:
            store.put_record(rec)
        return
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        for rec in records:
            f.write(json.dumps(asdict(rec), allow_nan=False) + "\n")
    os.replace(tmp, path)


class GoldenStore(RecordBackend):
    """Read-only view of an exported golden DB file (any format)."""

    backend_name = "golden"

    def __init__(self, path: str, machine: str | None = None):
        self.path = path
        super().__init__(os.path.dirname(path) or ".", machine=machine)

    def _load(self) -> None:
        if not os.path.exists(self.path):
            warnings.warn(f"golden DB {self.path} not found; the overlay "
                          f"is empty", ATRecordWarning, stacklevel=2)
            return
        for rec in read_records_file(self.path):
            self._remember(rec)

    def _append(self, rec: TuningRecord) -> None:
        raise RuntimeError(f"golden DB {self.path} is read-only; "
                           f"merge it into a local store instead")


class GoldenOverlayStore:
    """Read-through overlay: a writable local store over a read-only
    golden DB.  Precedence is *local record beats golden, golden beats
    cold*: lookups try the local store first, writes go only to it —
    re-tuned optima shadow the fleet's without mutating the shipped DB.
    """

    def __init__(self, primary: RecordBackend, golden: RecordBackend):
        self.primary = primary
        self.golden = golden

    @property
    def backend_name(self) -> str:
        return f"{self.primary.backend_name}+golden"

    @property
    def workdir(self) -> str:
        return self.primary.workdir

    @property
    def machine(self) -> str:
        return self.primary.machine

    @property
    def path(self):
        return getattr(self.primary, "path", None)

    # writes → local only
    def put(self, *args, **kwargs) -> TuningRecord:
        return self.primary.put(*args, **kwargs)

    def put_record(self, rec: TuningRecord) -> TuningRecord:
        return self.primary.put_record(rec)

    def merge_records(self, records, prefer: str = "better-cost"):
        return self.primary.merge_records(records, prefer=prefer)

    # reads → local first, golden fallback
    def lookup(self, phase: str, region: str,
               bp: dict[str, Any] | None = None) -> TuningRecord | None:
        return self.primary.lookup(phase, region, bp) \
            or self.golden.lookup(phase, region, bp)

    def lookup_all(self, phase: str, region: str) -> list[TuningRecord]:
        merged = {r.key: r for r in self.golden.lookup_all(phase, region)}
        merged.update(
            {r.key: r for r in self.primary.lookup_all(phase, region)})
        return list(merged.values())

    def records(self) -> Iterator[TuningRecord]:
        merged = {r.key: r for r in self.golden.records()}
        merged.update({r.key: r for r in self.primary.records()})
        return iter(merged.values())

    def regions(self, phase: str) -> list[str]:
        return sorted(set(self.primary.regions(phase))
                      | set(self.golden.regions(phase)))

    def __len__(self) -> int:
        return sum(1 for _ in self.records())

    def __contains__(self, key: tuple) -> bool:
        return key in self.primary or key in self.golden

    def export(self, path: str, machine: str | None = None,
               phase: str | None = None) -> int:
        recs = [r for r in self.records()
                if machine in (None, "all") or r.machine == machine]
        if phase is not None:
            recs = [r for r in recs if r.phase == phase]
        write_records_file(path, recs)
        return len(recs)

    def describe(self) -> dict:
        out = self.primary.describe()
        out["backend"] = self.backend_name
        out["records"] = len(self)
        out["golden"] = self.golden.path
        return out


def open_record_store(workdir: str = ".", *, backend: str = "jsonl",
                      machine: str | None = None,
                      golden_db: str | None = None):
    """Open the tuning DB for a workdir: a registered backend, optionally
    overlaid on a read-only golden DB (``golden_db`` path)."""
    store = record_backends.get(backend)(workdir, machine=machine)
    if golden_db:
        store = GoldenOverlayStore(
            store, GoldenStore(golden_db, machine=store.machine))
    return store
