"""Serving-bench regression gate: current run vs committed baseline.

Compares two ``BENCH_serving.json`` payloads cell by cell (cells are
keyed by arch x cache x workload x the per-workload mode columns:
prefill_chunk, spec_k, prefix_cache, kv_dtype, mesh, num_splits,
long_len) and fails when the current run regresses past the thresholds:

* throughput (``tokens_per_s``) drops by more than ``--max-tps-drop``
  (default 20%);
* p99 TTFT (``ttft_p99_s``) rises by more than ``--max-ttft-rise``
  (default 25%);
* a speculative cell's measured ``accept_rate`` falls to zero while the
  baseline's is positive (the draft/verify path stopped accepting —
  speculation degenerated into pure overhead);
* a shared-prefix cell's measured ``prefix_hit_rate`` falls to zero
  while the baseline's is positive (the hash index stopped matching —
  every admission re-prefills its shared system prompt);
* a gateway cell's ``goodput_tok_s`` (tokens/s from within-SLO requests)
  drops by more than ``--max-tps-drop``, or its ``slo_attainment`` falls
  to zero while the baseline's is positive (the gateway still moves
  tokens but none inside the latency SLO);
* a kv_dtype cell's ``capacity_tokens`` (resident tokens the pool holds
  at its fixed byte budget) drops below the baseline's — quantized pages
  stopped buying capacity — or its ``greedy_agreement`` (token-level
  match against the fp cell) falls by more than ``--max-agreement-drop``
  (default 5 points) — quantization started corrupting outputs;
* a long_context cell's ``greedy_agreement`` (the committed split-KV
  run's token-level match against the forced ``num_splits=1`` run)
  falls below 100% — the two-phase combine must reproduce the
  sequential kernel's greedy tokens exactly — or its ``itl_p50_s``
  rises past ``--max-itl-rise`` over the baseline (plus the
  ``--itl-floor`` jitter slack); long_context cells are exempt from the
  generic throughput gate — they keep their min-ITL repeat, and at one
  lane their tokens/s is mostly prefill wall;
* within the *current* payload, a long_context pair where the
  committed-splits cell's p50 ITL sits above the forced-sequential
  cell's past the same rise/floor allowance — the tuner committed a
  split degree slower than the kernel it replaced.

An absolute TTFT slack (``--ttft-floor``, default 50 ms) absorbs
scheduler jitter on cells whose TTFT is tiny: a rise only fails the gate
when the current value also exceeds ``baseline + floor``.  Cells present
in the baseline but missing from the current run fail the gate (a
silently dropped cell is a regression too); extra current cells are
reported but don't fail.

Both payloads carry the run shape under ``config`` (stamped by
``bench_serving.py``); the gate refuses to diff two benchmarks measured
with different workloads (exit 2) — regenerate against the matching
baseline instead of reading false regressions.  The same usage-error
exit (2, distinct from a measured regression's 1) covers payloads whose
*cell-key sets* disagree structurally — duplicate keys inside one
payload, or two payloads with no keys in common (wrong baseline file,
or a cell-schema change) — with a message naming the missing and extra
keys instead of an unexplained traceback.  Committed baselines:

* ``benchmarks/baselines/BENCH_serving_smoke.json`` — the CI smoke shape
  (``--requests 4 --max-new 5``), diffed by the ``bench-compare`` step;
* ``benchmarks/baselines/BENCH_serving.json`` — default flags, for local
  full runs.

Local usage (flags must match the baseline's shape)::

    PYTHONPATH=src python -m benchmarks.bench_serving \
        --out BENCH_serving.json --requests 4 --max-new 5
    PYTHONPATH=src python -m benchmarks.compare \
        benchmarks/baselines/BENCH_serving_smoke.json BENCH_serving.json
"""

from __future__ import annotations

import argparse
import json
import sys


def cell_key(row: dict) -> tuple:
    return (
        row.get("arch"),
        row.get("cache"),
        row.get("workload", "uniform"),
        row.get("prefill_chunk"),
        row.get("spec_k"),
        row.get("prefix_cache"),
        row.get("kv_dtype"),
        row.get("mesh"),
        row.get("num_splits"),
        row.get("long_len"),
    )


def _fmt_key(key: tuple) -> str:
    if len(key) != 10:  # malformed row: show it verbatim, don't traceback
        return repr(key)
    arch, cache, workload, chunk, spec_k, prefix_cache, kv_dtype, mesh, num_splits, long_len = key
    mode = f"/chunk={chunk}" if chunk else ""
    if spec_k is not None:
        mode += f"/k={spec_k}"
    if prefix_cache is not None:
        mode += f"/prefix={'on' if prefix_cache else 'off'}"
    if kv_dtype is not None:
        mode += f"/kv={kv_dtype}"
    if mesh is not None:
        mode += f"/mesh={mesh}"
    if long_len is not None:
        mode += f"/len={long_len}"
    if num_splits is not None:
        mode += f"/ns={num_splits}"
    return f"{arch}:{cache}:{workload}{mode}"


def load_payload(path: str) -> tuple[dict, dict[tuple, dict], list[tuple]]:
    """Parse one payload into (config, cells-by-key, duplicate-keys).
    Two rows mapping to the same cell key would silently shadow each
    other in the dict — the caller turns ``dupes`` into a usage error."""
    with open(path) as f:
        payload = json.load(f)
    cells: dict[tuple, dict] = {}
    dupes: list[tuple] = []
    for row in payload.get("results", []):
        key = cell_key(row)
        if key in cells:
            dupes.append(key)
        cells[key] = row
    return payload.get("config", {}), cells, dupes


def keyset_mismatch(baseline: dict[tuple, dict], current: dict[tuple, dict]) -> str | None:
    """A usage-error message when the two payloads' cell-key sets have
    nothing in common (wrong baseline file or a cell-schema change) —
    every baseline cell would read as 'missing' and every current cell
    as 'new', which is a comparison error, not a regression.  Partial
    overlap is left to the gate: a genuinely dropped cell must still
    fail it."""
    if not baseline or not current or (set(baseline) & set(current)):
        return None
    missing = ", ".join(_fmt_key(k) for k in sorted(baseline, key=str))
    extra = ", ".join(_fmt_key(k) for k in sorted(current, key=str))
    return (
        "payloads share no cell keys — missing (baseline-only): "
        f"[{missing}]; extra (current-only): [{extra}]; wrong "
        "baseline file or a cell-key schema change: regenerate the "
        "baseline with the matching bench_serving.py"
    )


def config_mismatch(base_cfg: dict, cur_cfg: dict) -> list[str]:
    """Workload-shape keys that differ (``repeats`` only affects noise,
    not the measured workload, so it is exempt)."""
    keys = (set(base_cfg) | set(cur_cfg)) - {"repeats"}
    return sorted(k for k in keys if base_cfg.get(k) != cur_cfg.get(k))


def split_itl_regressions(
    current: dict[tuple, dict],
    max_itl_rise: float = 0.25,
    itl_floor_s: float = 0.025,
) -> list[str]:
    """Within-payload gate on the long_context cell pairs: at each
    (arch, long_len) the committed-splits cell's p50 ITL must not sit
    above the forced-sequential cell's past the jitter allowance.  The
    tuner may *commit* ``num_splits=1`` when splitting doesn't pay, but
    it must never commit a split degree that makes decode slower than
    the kernel it replaced."""
    failures: list[str] = []
    pairs: dict[tuple, dict] = {}
    for row in current.values():
        if row.get("workload") != "long_context":
            continue
        pair = (row.get("arch"), row.get("long_len"))
        pairs.setdefault(pair, {})[str(row.get("num_splits"))] = row
    for (arch, long_len), modes in sorted(pairs.items(), key=str):
        seq, auto = modes.get("1"), modes.get("auto")
        if seq is None or auto is None:
            continue
        b_itl, c_itl = seq.get("itl_p50_s"), auto.get("itl_p50_s")
        if not b_itl or c_itl is None or c_itl <= b_itl + itl_floor_s:
            continue
        rise = (c_itl - b_itl) / b_itl
        if rise > max_itl_rise:
            failures.append(
                f"{arch}:paged:long_context/len={long_len}: committed "
                f"split-KV p50 ITL sits {rise:.0%} above the forced "
                f"num_splits=1 cell ({b_itl:.4f}s -> {c_itl:.4f}s; "
                f"limit {max_itl_rise:.0%}) — the tuner committed a "
                f"split degree slower than the sequential kernel"
            )
    return failures


def compare(
    baseline: dict[tuple, dict],
    current: dict[tuple, dict],
    max_tps_drop: float = 0.20,
    max_ttft_rise: float = 0.25,
    ttft_floor_s: float = 0.05,
    max_agreement_drop: float = 0.05,
    max_itl_rise: float = 0.25,
    itl_floor_s: float = 0.025,
) -> list[str]:
    """Return the list of failure messages (empty == gate passes)."""
    failures: list[str] = []
    for key, base in sorted(baseline.items(), key=lambda kv: str(kv[0])):
        cur = current.get(key)
        name = _fmt_key(key)
        if cur is None:
            failures.append(f"{name}: cell missing from current run")
            continue
        # long_context cells keep their min-ITL repeat, not best-of-tps,
        # and at 1 lane their tokens/s is mostly prefill wall — their
        # gates are the ITL pair + agreement checks below instead
        b_tps, c_tps = base.get("tokens_per_s"), cur.get("tokens_per_s")
        if b_tps and c_tps is not None and cur.get("workload") != "long_context":
            drop = (b_tps - c_tps) / b_tps
            if drop > max_tps_drop:
                failures.append(
                    f"{name}: throughput dropped {drop:.0%} "
                    f"({b_tps:.1f} -> {c_tps:.1f} tok/s; limit {max_tps_drop:.0%})"
                )
        b_ttft, c_ttft = base.get("ttft_p99_s"), cur.get("ttft_p99_s")
        if b_ttft and c_ttft is not None and c_ttft > b_ttft + ttft_floor_s:
            rise = (c_ttft - b_ttft) / b_ttft
            if rise > max_ttft_rise:
                failures.append(
                    f"{name}: p99 TTFT rose {rise:.0%} "
                    f"({b_ttft:.3f}s -> {c_ttft:.3f}s; limit {max_ttft_rise:.0%})"
                )
        b_ar, c_ar = base.get("accept_rate"), cur.get("accept_rate")
        if b_ar and not c_ar:
            failures.append(
                f"{name}: speculative accept rate fell to zero "
                f"(baseline {b_ar:.1%}) — drafts are pure overhead"
            )
        b_hr, c_hr = base.get("prefix_hit_rate"), cur.get("prefix_hit_rate")
        if b_hr and not c_hr:
            failures.append(
                f"{name}: prefix hit rate fell to zero "
                f"(baseline {b_hr:.1%}) — the index stopped matching and "
                f"every admission re-prefills its shared prompt"
            )
        b_gp, c_gp = base.get("goodput_tok_s"), cur.get("goodput_tok_s")
        if b_gp and c_gp is not None:
            gp_drop = (b_gp - c_gp) / b_gp
            if gp_drop > max_tps_drop:
                failures.append(
                    f"{name}: goodput dropped {gp_drop:.0%} "
                    f"({b_gp:.1f} -> {c_gp:.1f} good tok/s; "
                    f"limit {max_tps_drop:.0%})"
                )
        b_slo, c_slo = base.get("slo_attainment"), cur.get("slo_attainment")
        if b_slo and not c_slo:
            failures.append(
                f"{name}: SLO attainment fell to zero "
                f"(baseline {b_slo:.1%}) — tokens still flow but none "
                f"inside the latency SLO"
            )
        b_cap, c_cap = base.get("capacity_tokens"), cur.get("capacity_tokens")
        if b_cap and c_cap is not None and c_cap < b_cap:
            failures.append(
                f"{name}: pool capacity dropped {b_cap} -> {c_cap} "
                f"resident tokens at the fixed byte budget — quantized "
                f"pages stopped buying capacity"
            )
        b_agr = base.get("greedy_agreement")
        c_agr = cur.get("greedy_agreement")
        if b_agr and c_agr is not None and b_agr - c_agr > max_agreement_drop:
            failures.append(
                f"{name}: greedy agreement fell {b_agr:.1%} -> {c_agr:.1%} "
                f"(limit {max_agreement_drop:.0%} drop) — quantized pages "
                f"are corrupting outputs"
            )
        # mesh cells carry a stricter invariant than the kv-precision
        # drop limit: sharded serving must be BIT-identical to the
        # unsharded engine, so any divergence at all is a failure
        if cur.get("workload") == "mesh" and c_agr is not None and c_agr < 1.0:
            failures.append(
                f"{name}: tensor-parallel outputs diverged from "
                f"single-device greedy truth (agreement {c_agr:.1%}; the "
                f"sharded dispatch must be bit-identical)"
            )
        # long_context cells carry the split-KV invariants: the
        # committed-splits run must agree with the forced-sequential
        # outputs exactly (the combine is exact up to fp32 rounding and
        # greedy argmax must not flip), and its per-step latency — the
        # number the split axis exists to shorten — must not regress
        # against the baseline past the jitter allowance
        if cur.get("workload") == "long_context":
            if c_agr is not None and c_agr < 1.0:
                failures.append(
                    f"{name}: split-KV outputs diverged from the "
                    f"forced num_splits=1 greedy truth (agreement "
                    f"{c_agr:.1%}; the two-phase combine must "
                    f"reproduce the sequential kernel's tokens)"
                )
            b_itl, c_itl = base.get("itl_p50_s"), cur.get("itl_p50_s")
            if b_itl and c_itl is not None and c_itl > b_itl + itl_floor_s:
                rise = (c_itl - b_itl) / b_itl
                if rise > max_itl_rise:
                    failures.append(
                        f"{name}: p50 ITL rose {rise:.0%} "
                        f"({b_itl:.4f}s -> {c_itl:.4f}s; limit {max_itl_rise:.0%})"
                    )
    failures.extend(split_itl_regressions(current, max_itl_rise, itl_floor_s))
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_serving.json baseline")
    ap.add_argument("current", help="freshly produced BENCH_serving.json")
    ap.add_argument(
        "--max-tps-drop",
        type=float,
        default=0.20,
        help="max allowed fractional throughput drop",
    )
    ap.add_argument(
        "--max-ttft-rise",
        type=float,
        default=0.25,
        help="max allowed fractional p99-TTFT rise",
    )
    ap.add_argument(
        "--ttft-floor",
        type=float,
        default=0.05,
        help="absolute TTFT slack in seconds (jitter floor)",
    )
    ap.add_argument(
        "--max-agreement-drop",
        type=float,
        default=0.05,
        help="max allowed drop in a kv_dtype cell's greedy agreement",
    )
    ap.add_argument(
        "--max-itl-rise",
        type=float,
        default=0.25,
        help="max allowed fractional p50-ITL rise on long_context cells",
    )
    ap.add_argument(
        "--itl-floor",
        type=float,
        default=0.025,
        help="absolute p50-ITL slack in seconds (long_context jitter floor)",
    )
    args = ap.parse_args()

    base_cfg, baseline, base_dupes = load_payload(args.baseline)
    cur_cfg, current, cur_dupes = load_payload(args.current)
    for label, path, dupes in (
        ("baseline", args.baseline, base_dupes),
        ("current", args.current, cur_dupes),
    ):
        if dupes:
            named = ", ".join(_fmt_key(k) for k in dupes)
            print(
                f"[bench-compare] ERROR: {label} payload {path} has "
                f"duplicate cell keys ({named}) — rows shadow each other, "
                "the comparison would be against whichever came last"
            )
            sys.exit(2)
    mismatched = config_mismatch(base_cfg, cur_cfg)
    if mismatched:
        print(
            "[bench-compare] ERROR: baseline and current were generated "
            f"with different workload shapes (differing: "
            f"{', '.join(mismatched)}); regenerate against the matching "
            "baseline instead of reading false regressions"
        )
        sys.exit(2)
    disjoint = keyset_mismatch(baseline, current)
    if disjoint:
        print(f"[bench-compare] ERROR: {disjoint}")
        sys.exit(2)
    for key in sorted(set(current) - set(baseline), key=str):
        print(f"[bench-compare] new cell (no baseline): {_fmt_key(key)}")

    failures = compare(
        baseline,
        current,
        args.max_tps_drop,
        args.max_ttft_rise,
        args.ttft_floor,
        args.max_agreement_drop,
        args.max_itl_rise,
        args.itl_floor,
    )
    compared = len(set(baseline) & set(current))
    if failures:
        for msg in failures:
            print(f"[bench-compare] FAIL {msg}")
        print(
            f"[bench-compare] {len(failures)} regression(s) across "
            f"{compared} compared cell(s)"
        )
        sys.exit(1)
    print(
        f"[bench-compare] OK: {compared} cell(s) within thresholds "
        f"(tps drop <= {args.max_tps_drop:.0%}, "
        f"p99 TTFT rise <= {args.max_ttft_rise:.0%})"
    )


if __name__ == "__main__":
    main()
