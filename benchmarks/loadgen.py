"""Closed-loop load generator for the serving gateway.

Drives the HTTP/SSE gateway the way production traffic does, not the way
a benchmark harness does:

* **Poisson arrivals** — sessions arrive with exponential inter-arrival
  times at ``--rate`` sessions/s (open-loop arrivals, so queueing delay
  is real and the admission queue actually fills);
* **heavy-tailed lengths** — prompt and output lengths are lognormal
  (clipped), so a few long requests ride among many short ones;
* **multi-turn sessions** — each session runs ``--turns`` requests
  *closed-loop* (turn N+1 starts only after turn N streams out, plus a
  think-time gap), and every turn's prompt is the previous turn's full
  prompt + generated tokens + a fresh user chunk — the growing shared
  history is exactly the workload the prefix cache serves from its hash
  index;
* **backpressure aware** — a 429 bounce sleeps the advertised
  ``Retry-After`` and retries; bounces are counted, not hidden.

Everything is measured **client-side** (wall-clock across the socket):
queue-wait comes back in the server's ``done`` frame, TTFT/ITL from SSE
frame arrival times.  The report carries the percentile quartet plus the
two serving-quality numbers ``compare.py`` gates: **SLO attainment**
(fraction of requests with TTFT and p95 ITL inside the SLO) and
**goodput** (tokens/s counting only within-SLO requests).

``--in-process`` starts a reduced-config engine + gateway on an
ephemeral localhost port inside this process (real TCP, real SSE) and
tears it down after the run — the CI smoke path and the
``bench_serving.py`` gateway cells both use it.

Usage::

    PYTHONPATH=src python benchmarks/loadgen.py --in-process \
        --requests 200 --rate 50 --turns 2 [--json out.json]
    PYTHONPATH=src python benchmarks/loadgen.py --host H --port P ...
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402


def _pcts(xs) -> dict:
    from repro.serving.metrics import percentile
    xs = list(xs)
    if not xs:
        return {"p50": None, "p95": None, "p99": None, "mean": None}
    return {"p50": percentile(xs, 50), "p95": percentile(xs, 95),
            "p99": percentile(xs, 99),
            "mean": float(np.asarray(xs, np.float64).mean())}


def _lognormal_int(rng, mean: float, sigma: float, lo: int, hi: int) -> int:
    # lognormal with the given *linear-scale* mean: mu = ln(mean) - s^2/2
    x = rng.lognormal(np.log(mean) - sigma * sigma / 2.0, sigma)
    return int(np.clip(round(x), lo, hi))


class _Record:
    __slots__ = ("tokens", "ttft_s", "itl_s", "queue_wait_s",
                 "cached_tokens", "bounces", "ok")

    def __init__(self):
        self.tokens = 0
        self.ttft_s = None
        self.itl_s = []
        self.queue_wait_s = None
        self.cached_tokens = 0
        self.bounces = 0
        self.ok = False


async def _one_turn(host: str, port: int, prompt: list[int],
                    max_new: int, seed: int) -> tuple[_Record, list[int]]:
    """One closed-loop request: POST, stream, retry on 429."""
    from repro.serving.gateway import sse_generate
    rec = _Record()
    out: list[int] = []
    while True:
        t0 = time.monotonic()
        last_t = None
        final = None
        async for kind, payload in sse_generate(
                host, port, prompt, max_new_tokens=max_new,
                sampling={"temperature": 0.0, "seed": seed}):
            now = time.monotonic()
            if kind == "tokens":
                if rec.ttft_s is None:
                    rec.ttft_s = now - t0
                elif last_t is not None:
                    rec.itl_s.append((now - last_t) / max(len(payload), 1))
                last_t = now
                out.extend(payload)
                rec.tokens += len(payload)
            else:
                final = (kind, payload)
        if final and final[0] == "http_error":
            if final[1]["status"] == 429:
                rec.bounces += 1
                await asyncio.sleep(float(final[1].get("retry_after") or 1))
                continue
            return rec, out            # non-retryable: dropped request
        if final and final[0] == "done":
            rec.ok = True
            rec.queue_wait_s = final[1].get("queue_wait_s")
            rec.cached_tokens = final[1].get("cached_tokens") or 0
        return rec, out


async def run_load(host: str, port: int, *, n_requests: int = 200,
                   rate: float = 50.0, turns: int = 1, seed: int = 0,
                   vocab: int = 1000, prompt_mean: float = 12.0,
                   prompt_sigma: float = 0.6, max_prompt: int = 48,
                   out_mean: float = 8.0, out_sigma: float = 0.6,
                   max_out: int = 24, think_s: float = 0.01,
                   history_cap: int = 96,
                   slo_ttft_s: float = 30.0, slo_itl_s: float = 5.0,
                   shared_prefix: int = 0) -> dict:
    """Drive the gateway with ``n_requests`` total turns; returns the
    client-side report (percentiles, SLO attainment, goodput)."""
    from repro.serving.metrics import percentile

    rng = np.random.default_rng(seed)
    n_sessions = max(1, -(-n_requests // turns))
    # open-loop Poisson session arrivals
    gaps = rng.exponential(1.0 / rate, size=n_sessions)
    arrivals = np.cumsum(gaps)
    prefix = rng.integers(0, vocab, size=shared_prefix).tolist() \
        if shared_prefix else []
    records: list[_Record] = []
    t_start = time.monotonic()

    async def session(i: int) -> None:
        await asyncio.sleep(max(0.0, arrivals[i] - (time.monotonic()
                                                    - t_start)))
        srng = np.random.default_rng(seed * 7919 + i)
        history = list(prefix)
        for t in range(turns):
            if len(records) >= n_requests:
                return
            p_len = _lognormal_int(srng, prompt_mean, prompt_sigma,
                                   4, max_prompt)
            o_len = _lognormal_int(srng, out_mean, out_sigma, 1, max_out)
            prompt = history + srng.integers(0, vocab, size=p_len).tolist()
            rec, out = await _one_turn(host, port, prompt, o_len,
                                       seed + i * 101 + t)
            records.append(rec)
            # next turn re-hits this prefix; cap keeps prompt + budget
            # inside the engine's max_len (keep the *front*: that is the
            # part the prefix cache has pages for)
            history = (prompt + out)[:history_cap]
            if t + 1 < turns:
                await asyncio.sleep(srng.exponential(think_s))

    await asyncio.gather(*[session(i) for i in range(n_sessions)])
    wall = time.monotonic() - t_start

    done = [r for r in records if r.ok]
    slo_ok = [r for r in done
              if r.ttft_s is not None and r.ttft_s <= slo_ttft_s
              and (not r.itl_s
                   or percentile(r.itl_s, 95) <= slo_itl_s)]
    good_tokens = sum(r.tokens for r in slo_ok)
    all_tokens = sum(r.tokens for r in done)
    return {
        "requests": len(records),
        "completed": len(done),
        "rejected_429": sum(r.bounces for r in records),
        "sessions": n_sessions,
        "turns": turns,
        "arrival_rate_per_s": rate,
        "wall_s": wall,
        "generated_tokens": all_tokens,
        "tokens_per_s": all_tokens / wall if wall > 0 else 0.0,
        "goodput_tok_s": good_tokens / wall if wall > 0 else 0.0,
        "slo_ok": len(slo_ok),
        "slo_attainment": len(slo_ok) / len(done) if done else 0.0,
        "slo_ttft_s": slo_ttft_s,
        "slo_itl_s": slo_itl_s,
        "queue_wait_s": _pcts(r.queue_wait_s for r in done
                              if r.queue_wait_s is not None),
        "ttft_s": _pcts(r.ttft_s for r in done if r.ttft_s is not None),
        "itl_s": _pcts(x for r in done for x in r.itl_s),
        "prefix_hit_tokens": sum(r.cached_tokens for r in done),
    }


async def run_in_process(*, arch: str = "yi-6b", n_lanes: int = 4,
                         max_len: int = 192, queue_limit: int = 32,
                         policy_window: int = 2, autotune: bool = False,
                         workdir: str = ".", seed: int = 0,
                         prefix_cache: bool = True, **load_kw) -> dict:
    """Start engine + gateway in-process on an ephemeral port, run the
    load against it over real localhost TCP, drain, and merge the
    server-side view (ticks, policy, engine queue-wait percentiles) into
    the client-side report."""
    import jax

    from repro.configs import get_arch
    from repro.launch.serve import _make_autotuner
    from repro.models import build_model
    from repro.serving import ServingEngine
    from repro.serving.gateway import GatewayServer, PipelinedEngine

    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    tuner = _make_autotuner(model, workdir, "paged", 16, gateway=True,
                            prefill_chunk=16) if autotune else None
    engine = ServingEngine(model, params, n_lanes=n_lanes, max_len=max_len,
                           autotuner=tuner, cache="paged", page_size=16,
                           timeslice=16, prefill_chunk=16,
                           prefix_cache=prefix_cache)
    pipe = PipelinedEngine(engine, queue_limit=queue_limit, tuner=tuner,
                           policy_window=policy_window,
                           slo_ttft_s=load_kw.get("slo_ttft_s", 30.0),
                           slo_itl_s=load_kw.get("slo_itl_s", 5.0))
    srv = GatewayServer(pipe)
    await srv.start()
    try:
        report = await run_load("127.0.0.1", srv.port,
                                vocab=cfg.vocab_size, seed=seed, **load_kw)
    finally:
        await srv.drain()
    summary = engine.metrics.summary()
    report["server"] = {
        **{k: v for k, v in pipe.stats().items() if k != "draining"},
        "queue_wait_s": summary["queue_wait_s"],
        "preemptions": summary["preemptions"],
        "prefix_cache": summary["prefix_cache"],
        "committed_gateway": (tuner.committed_gateway_params()
                              if tuner else None),
    }
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--in-process", action="store_true",
                    help="start a reduced-config engine + gateway on an "
                         "ephemeral port and load-test it (CI smoke)")
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="Poisson session-arrival rate (sessions/s)")
    ap.add_argument("--turns", type=int, default=2,
                    help="closed-loop turns per session (multi-turn "
                         "history re-hits the prefix cache)")
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--max-out", type=int, default=24)
    ap.add_argument("--queue-limit", type=int, default=32)
    ap.add_argument("--autotune", action="store_true",
                    help="in-process: tune GatewayPolicy during the run")
    ap.add_argument("--workdir", default=".",
                    help="in-process: AT session workdir")
    ap.add_argument("--slo-ttft", type=float, default=30.0)
    ap.add_argument("--slo-itl", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None,
                    help="write the report to this path as JSON")
    args = ap.parse_args()
    load_kw = dict(n_requests=args.requests, rate=args.rate,
                   turns=args.turns, seed=args.seed,
                   max_prompt=args.max_prompt, max_out=args.max_out,
                   slo_ttft_s=args.slo_ttft, slo_itl_s=args.slo_itl)
    if args.in_process:
        report = asyncio.run(run_in_process(
            arch=args.arch, queue_limit=args.queue_limit,
            autotune=args.autotune, workdir=args.workdir, **load_kw))
    else:
        report = asyncio.run(run_load(args.host, args.port, **load_kw))
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
    print(f"[loadgen] {report['completed']}/{report['requests']} requests "
          f"({report['sessions']} sessions x {report['turns']} turns), "
          f"{report['generated_tokens']} tokens in "
          f"{report['wall_s']:.1f}s: goodput "
          f"{report['goodput_tok_s']:.1f} tok/s, SLO "
          f"{report['slo_attainment']:.0%}, {report['rejected_429']} "
          f"bounced, queue p50 "
          f"{report['queue_wait_s']['p50'] if report['queue_wait_s']['p50'] is not None else float('nan'):.3f}s, "
          f"ttft p50 "
          f"{report['ttft_s']['p50'] if report['ttft_s']['p50'] is not None else float('nan'):.3f}s")


if __name__ == "__main__":
    main()
