"""Benchmark harness — ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/claim (bench_paper_tables) plus kernel
micro-benchmarks (interpret mode; CPU-proxy numbers) and the roofline
emitters (read from dry-run artifacts when present).

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))


def bench_kernels() -> list[tuple[str, float, str]]:
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.matmul import matmul

    key = jax.random.PRNGKey(0)
    rows = []
    x = jax.random.normal(key, (256, 256), jnp.float32)
    y = jax.random.normal(key, (256, 256), jnp.float32)
    out = matmul(x, y, block_m=128, block_n=128, block_k=128,
                 interpret=True)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(3):
        jax.block_until_ready(matmul(x, y, block_m=128, block_n=128,
                                     block_k=128, interpret=True))
    us = (time.perf_counter() - t0) * 1e6 / 3
    rows.append(("pallas_matmul_256_interp", us,
                 "CPU-proxy (interpret mode); TPU is the target"))

    q = jax.random.normal(key, (1, 4, 256, 64), jnp.float32) * 0.3
    out = flash_attention(q, q, q, block_q=128, block_k=128, interpret=True)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    jax.block_until_ready(flash_attention(q, q, q, block_q=128, block_k=128,
                                          interpret=True))
    rows.append(("pallas_flash_256_interp", (time.perf_counter() - t0) * 1e6,
                 "CPU-proxy (interpret mode)"))

    # jnp reference path wall-time (the actual CPU execution path)
    t0 = time.perf_counter()
    jax.block_until_ready(ref.attention_ref(q, q, q))
    rows.append(("ref_attention_256", (time.perf_counter() - t0) * 1e6,
                 "jnp oracle"))
    return rows


def bench_roofline_summary() -> list[tuple[str, float, str]]:
    art = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "artifacts")
    if not os.path.isdir(art):
        return [("roofline", 0.0, "no artifacts (run repro.launch.dryrun)")]
    from repro.launch.roofline import table
    rows = []
    for r in table(art, "single"):
        if r.skipped:
            continue
        rows.append((f"roofline[{r.arch}|{r.shape}]",
                     r.bound_s * 1e6,
                     f"dominant={r.dominant} "
                     f"frac={100 * r.roofline_fraction:.1f}% "
                     f"plan={r.plan}"))
    return rows


def bench_train_throughput() -> list[tuple[str, float, str]]:
    from repro.launch.train import train
    out = train(arch="h2o-danube-1.8b", steps=6, seq_len=64, batch=4,
                log_every=100)
    tokens = 6 * 64 * 4
    us = out["wall_s"] * 1e6 / 6
    return [("train_step_reduced_danube", us,
             f"{tokens / out['wall_s']:.0f} tok/s CPU-proxy, "
             f"final_loss={out['final_loss']:.3f}")]


def main() -> None:
    from benchmarks.bench_paper_tables import ALL
    sections = ALL + [bench_kernels, bench_train_throughput,
                      bench_roofline_summary]
    print("name,us_per_call,derived")
    for fn in sections:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:          # report, keep benching
            print(f"{fn.__name__},NaN,ERROR {type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
