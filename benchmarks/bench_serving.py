"""Serving benchmark — dense vs paged engine, ``BENCH_serving.json``.

Runs the serving stack end-to-end (prefill, scheduler, KV backend, decode
dispatch) for the dense and paged engines on at least two reduced
configs, and emits the serving-latency quartet per cell: tokens/s, p50/p99
TTFT, p50/p99 inter-token latency.  Numbers are CPU-proxy (interpret-mode
kernels on reduced configs) — the *trajectory* across PRs is the signal,
not the absolute values.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_serving \
        [--out BENCH_serving.json] [--requests 6] [--max-new 8]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

DEFAULT_ARCHS = ("yi-6b", "deepseek-7b")


def bench_one(arch: str, cache: str, n_requests: int, n_lanes: int,
              max_len: int, max_new: int, page_size: int,
              timeslice: int | None, seed: int = 0) -> dict:
    import jax
    import numpy as np

    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    # undersized page pool (~60% of lane parity, floor: one full sequence
    # + null page + slack) so the paged engine actually experiences page
    # pressure rather than degenerating to dense
    n_pages = None
    if cache == "paged":
        blocks_per_seq = -(-max_len // page_size)
        parity = n_lanes * blocks_per_seq + 1
        n_pages = max(blocks_per_seq + 2, int(parity * 0.6))
    engine = ServingEngine(model, params, n_lanes=n_lanes, max_len=max_len,
                           cache=cache, n_pages=n_pages,
                           page_size=page_size, timeslice=timeslice)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for rid in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(4, 12))).tolist()
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=max_new))
    finished = engine.run(max_steps=n_requests * (max_new + 6))
    wall = time.time() - t0
    s = engine.metrics.summary()
    return {
        "arch": arch, "cache": cache, "n_lanes": n_lanes,
        "requests": n_requests, "finished": len(finished),
        "decode_steps": engine.steps,
        "generated_tokens": s["generated_tokens"],
        "tokens_per_s": s["generated_tokens"] / wall if wall else 0.0,
        "ttft_p50_s": s["ttft_s"]["p50"], "ttft_p99_s": s["ttft_s"]["p99"],
        "itl_p50_s": s["itl_s"]["p50"], "itl_p99_s": s["itl_s"]["p99"],
        "preemptions": s["preemptions"],
        "cache_stats": engine.kv.stats(),
        "wall_s": wall,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--archs", nargs="+", default=list(DEFAULT_ARCHS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--lanes", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--timeslice", type=int, default=4)
    args = ap.parse_args()

    results = []
    for arch in args.archs:
        for cache in ("dense", "paged"):
            ts = args.timeslice if cache == "paged" else None
            row = bench_one(arch, cache, args.requests, args.lanes,
                            args.max_len, args.max_new, args.page_size, ts)
            results.append(row)

            def fmt(x, spec):
                return format(x, spec) if x is not None else "n/a"

            print(f"[bench_serving] {arch:14s} {cache:6s} "
                  f"{row['tokens_per_s']:8.1f} tok/s  "
                  f"ttft p50 {fmt(row['ttft_p50_s'], '.3f')}s "
                  f"p99 {fmt(row['ttft_p99_s'], '.3f')}s  "
                  f"itl p50 {fmt(row['itl_p50_s'], '.4f')}s  "
                  f"preempt {row['preemptions']}")

    payload = {"benchmark": "serving", "results": results}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[bench_serving] wrote {args.out} ({len(results)} cells)")


if __name__ == "__main__":
    main()
