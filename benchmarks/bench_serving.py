"""Serving benchmark — dense vs paged vs chunked-prefill engines.

Runs the serving stack end-to-end (prefill, scheduler, KV backend, decode
dispatch) and emits ``BENCH_serving.json``:

* **uniform** cells — dense vs paged engines on short uniform prompts
  (the serving-latency quartet: tokens/s, p50/p99 TTFT, p50/p99 ITL);
* **mixed** cells — one long prompt ahead of several short ones on the
  paged engine, monolithic prefill vs chunked prefill.  The headline
  number is ``ttft_short_p50_s``: with chunked prefill the short requests
  decode while the long prompt streams in chunk by chunk, so their TTFT
  must drop vs the head-of-line-blocked monolithic run.
* **spec** cells — greedy speculative decoding on the paged engine, one
  cell per draft length k: a self-speculative draft (the target's
  leading layers) proposes k tokens per tick and the target verifies
  them in one chunked call.  Cells report tokens/s per k plus the
  measured ``accept_rate``, which ``compare.py`` gates above zero.  Spec
  cells run a 4-layer variant of the reduced config (draft = 3 layers):
  acceptance is a draft/target *agreement* property, and at random init
  a 1-of-2-layer draft almost never agrees while 3-of-4 reliably does.
* **gateway** cells — closed-loop load (``loadgen.py`` in-process)
  through the async HTTP/SSE gateway: Poisson session arrivals,
  heavy-tailed lengths, multi-turn prefix re-hits, bounded admission
  queue.  Headline numbers are ``slo_attainment`` and ``goodput_tok_s``
  (tokens/s from within-SLO requests), both gated by ``compare.py``;
  latencies in these cells are client-side (queueing + network +
  compute).
* **kv_dtype** cells — fp vs int8 KV pages on the paged engine at a
  *fixed byte budget*: both cells get the same pool bytes, so the int8
  cell (int8 pages + per-row fp32 scales, dequantized in-kernel) buys
  ~3x the pages and admits more concurrent tokens before swapping.
  Cells report ``capacity_tokens`` / ``max_concurrent_seqs`` / swap
  counts plus ``greedy_agreement`` — the int8 cell's token-level match
  against the fp cell's greedy outputs — both gated by ``compare.py``.
* **mesh** cells — tensor-parallel paged serving over a forced-host
  2x2 device mesh (4 CPU devices, KV-head axis sharded over the model
  axis).  Each cell runs in a subprocess (``XLA_FLAGS`` must force the
  device count before jax initializes) that serves the same greedy
  workload unsharded and on the mesh; the cell reports the mesh run's
  throughput plus ``greedy_agreement`` — its token-level match against
  the unsharded outputs, which the sharded dispatch keeps bit-identical
  (gated by ``compare.py``).
* **long_context** cells — 1-lane long-prompt decode on the paged
  engine, one cell pair per ``--long-lens`` entry: split-KV committed
  (run-time AT over the ``num_splits`` ladder, warm-loaded for the
  timed repeats) vs forced ``num_splits=1`` (the sequential kernel
  spelling).  Headline is ``itl_p50_s`` — the per-step critical path
  the Flash-Decoding split axis shortens; ``compare.py`` gates the
  committed cell's greedy agreement against forced-1 at 100% and its
  p50 ITL at <= forced-1.
* **shared_prefix** cells — every request carries the same long system
  prompt (the production shape: few-shot templates, multi-turn history)
  on the chunked paged engine, prefix cache off vs on.  The cached cell
  reports ``prefix_hit_rate`` (gated above zero by ``compare.py``) and
  its headline is ``ttft_p50_s``: admissions that seed the shared-prompt
  pages from the hash index skip those prefill chunks entirely.

Numbers are CPU-proxy (interpret-mode kernels on reduced configs) — the
*trajectory* across PRs is the signal, not the absolute values.
``benchmarks/compare.py`` gates that trajectory in CI against the
committed baseline.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_serving \
        [--out BENCH_serving.json] [--requests 6] [--max-new 8]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

DEFAULT_ARCHS = ("yi-6b", "deepseek-7b")


def bench_one(arch: str, cache: str, n_requests: int, n_lanes: int,
              max_len: int, max_new: int, page_size: int,
              timeslice: int | None, seed: int = 0) -> dict:
    import jax
    import numpy as np

    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    # undersized page pool (~60% of lane parity, floor: one full sequence
    # + null page + slack) so the paged engine actually experiences page
    # pressure rather than degenerating to dense
    n_pages = None
    if cache == "paged":
        blocks_per_seq = -(-max_len // page_size)
        parity = n_lanes * blocks_per_seq + 1
        n_pages = max(blocks_per_seq + 2, int(parity * 0.6))
    engine = ServingEngine(model, params, n_lanes=n_lanes, max_len=max_len,
                           cache=cache, n_pages=n_pages,
                           page_size=page_size, timeslice=timeslice)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for rid in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(4, 12))).tolist()
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=max_new))
    finished = engine.run(max_steps=n_requests * (max_new + 6))
    wall = time.time() - t0
    s = engine.metrics.summary()
    return {
        "arch": arch, "cache": cache, "workload": "uniform",
        "n_lanes": n_lanes,
        "requests": n_requests, "finished": len(finished),
        "decode_steps": engine.steps,
        "generated_tokens": s["generated_tokens"],
        "tokens_per_s": s["generated_tokens"] / wall if wall else 0.0,
        "ttft_p50_s": s["ttft_s"]["p50"], "ttft_p99_s": s["ttft_s"]["p99"],
        "itl_p50_s": s["itl_s"]["p50"], "itl_p99_s": s["itl_s"]["p99"],
        "preemptions": s["preemptions"],
        "cache_stats": engine.kv.stats(),
        "wall_s": wall,
    }


def bench_kv_dtype(arch: str, kv_dtype: str, n_requests: int, n_lanes: int,
                   max_len: int, max_new: int, page_size: int,
                   timeslice: int | None, seed: int = 0):
    """fp vs int8 KV pages at a fixed byte budget (one cell per dtype).

    The budget is what the *fp* pool would spend at the uniform cells'
    undersized parity; each precision buys as many pages as fit in it.
    int8 pages cost ~1/3 the bytes (int8 payload + per-row fp32 scales
    vs f32), so the int8 cell runs the identical workload with ~3x the
    pages — more resident tokens, fewer preemption swaps.

    Returns ``(row, outputs)`` — outputs maps rid -> greedy tokens so
    the caller can score the int8 cell's agreement against the fp cell.
    """
    import jax
    import numpy as np

    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    blocks_per_seq = -(-max_len // page_size)

    def per_page_bytes(quantized):
        caches = jax.eval_shape(
            lambda: model.init_paged_caches(4, page_size,
                                            quantized=quantized))
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(caches)) / 4

    parity = n_lanes * blocks_per_seq + 1
    budget = max(blocks_per_seq + 2, int(parity * 0.6)) \
        * per_page_bytes(False)
    n_pages = max(blocks_per_seq + 2,
                  int(budget // per_page_bytes(kv_dtype == "int8")))
    engine = ServingEngine(model, params, n_lanes=n_lanes, max_len=max_len,
                           cache="paged", n_pages=n_pages,
                           page_size=page_size, timeslice=timeslice,
                           kv_dtype=kv_dtype)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for rid in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(4, 12))).tolist()
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=max_new))
    finished = engine.run(max_steps=n_requests * (max_new + 6))
    wall = time.time() - t0
    s = engine.metrics.summary()
    st = engine.kv.stats()
    row = {
        "arch": arch, "cache": "paged", "workload": "kv_dtype",
        "kv_dtype": kv_dtype, "n_lanes": n_lanes,
        "budget_bytes": int(budget), "n_pages": n_pages,
        "pool_bytes": st["pool_bytes"],
        "kv_bytes_per_token": st["kv_bytes_per_token"],
        "capacity_tokens": st["capacity_tokens"],
        "max_concurrent_seqs": (n_pages - 1) // blocks_per_seq,
        "swap_outs": st["swap_outs"], "swap_ins": st["swap_ins"],
        "requests": n_requests, "finished": len(finished),
        "decode_steps": engine.steps,
        "generated_tokens": s["generated_tokens"],
        "tokens_per_s": s["generated_tokens"] / wall if wall else 0.0,
        "ttft_p50_s": s["ttft_s"]["p50"], "ttft_p99_s": s["ttft_s"]["p99"],
        "itl_p50_s": s["itl_s"]["p50"], "itl_p99_s": s["itl_s"]["p99"],
        "preemptions": s["preemptions"],
        "wall_s": wall,
    }
    outputs = {r.rid: list(r.out_tokens) for r in finished}
    return row, outputs


# runs in a child interpreter: XLA_FLAGS (forced host device count) only
# takes effect before jax initializes, and the parent has already imported
# jax by the time the mesh cells run
_MESH_CHILD = r"""
import json, sys, time
import numpy as np

cfg_in = json.loads(sys.argv[1])
sys.path.insert(0, cfg_in["src"])
import jax
from repro.configs import get_arch
from repro.distributed.sharding import make_serving_mesh
from repro.models import build_model
from repro.serving import Request, ServingEngine

arch = cfg_in["arch"]
acfg = get_arch(arch).reduced()
model = build_model(acfg)
params = model.init(jax.random.PRNGKey(cfg_in["seed"]))

def run(mesh_spec):
    engine = ServingEngine(model, params, n_lanes=cfg_in["lanes"],
                           max_len=cfg_in["max_len"], cache="paged",
                           page_size=cfg_in["page_size"],
                           prefill_chunk=cfg_in["prefill_chunk"],
                           mesh=make_serving_mesh(mesh_spec))
    rng = np.random.default_rng(cfg_in["seed"])
    t0 = time.time()
    for rid in range(cfg_in["requests"]):
        prompt = rng.integers(0, acfg.vocab_size,
                              size=int(rng.integers(4, 12))).tolist()
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=cfg_in["max_new"]))
    finished = engine.run(
        max_steps=cfg_in["requests"] * (cfg_in["max_new"] + 6))
    wall = time.time() - t0
    s = engine.metrics.summary()
    outs = {int(r.rid): [int(t) for t in r.out_tokens] for r in finished}
    return engine, s, outs, wall, len(finished)

_, _, ref_outs, _, _ = run(None)
engine, s, outs, wall, n_fin = run(cfg_in["mesh"])
match = total = 0
for rid, ref in ref_outs.items():
    got = outs.get(rid, [])
    total += max(len(ref), len(got))
    match += sum(a == b for a, b in zip(ref, got))
print("MESH_ROW " + json.dumps({
    "n_devices": len(jax.devices()),
    "finished": n_fin,
    "decode_steps": engine.steps,
    "prefill_chunks": engine.prefill_chunks,
    "generated_tokens": s["generated_tokens"],
    "tokens_per_s": s["generated_tokens"] / wall if wall else 0.0,
    "ttft_p50_s": s["ttft_s"]["p50"], "ttft_p99_s": s["ttft_s"]["p99"],
    "itl_p50_s": s["itl_s"]["p50"], "itl_p99_s": s["itl_s"]["p99"],
    "preemptions": s["preemptions"],
    "greedy_agreement": match / total if total else 1.0,
    "wall_s": wall,
}))
"""


def bench_mesh(arch: str, mesh: str, n_requests: int, n_lanes: int,
               max_len: int, max_new: int, page_size: int,
               prefill_chunk: int, seed: int = 0) -> dict:
    """Tensor-parallel paged serving on a forced-host device mesh.

    The subprocess serves the identical greedy workload unsharded and on
    the mesh, so ``greedy_agreement`` scores the sharded dispatch
    against single-device truth (1.0 = bit-identical, the design
    invariant the kernels' head-sharded shard_map + exact all-gather
    guarantees)."""
    import subprocess

    devices = 1
    for d in mesh.split("x"):
        devices *= int(d)
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    child_cfg = {"src": env["PYTHONPATH"], "arch": arch, "mesh": mesh,
                 "requests": n_requests, "lanes": n_lanes,
                 "max_len": max_len, "max_new": max_new,
                 "page_size": page_size, "prefill_chunk": prefill_chunk,
                 "seed": seed}
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_CHILD, json.dumps(child_cfg)],
        capture_output=True, text=True, timeout=900, env=env)
    if proc.returncode != 0:
        raise RuntimeError(f"mesh bench child failed:\n{proc.stderr}")
    line = next(ln for ln in proc.stdout.splitlines()
                if ln.startswith("MESH_ROW "))
    row = json.loads(line[len("MESH_ROW "):])
    return {"arch": arch, "cache": "paged", "workload": "mesh",
            "mesh": mesh, "prefill_chunk": prefill_chunk,
            "n_lanes": n_lanes, "requests": n_requests, **row}


def bench_mixed(arch: str, prefill_chunk: int | None, n_short: int,
                n_lanes: int, max_len: int, max_new: int, page_size: int,
                long_len: int = 48, seed: int = 0) -> dict:
    """Mixed workload: one long prompt submitted *ahead of* short ones.

    Monolithic prefill (``prefill_chunk=None``) head-of-line-blocks the
    shorts behind the long prompt's one-shot prefill; chunked prefill
    streams the long prompt in while the shorts decode.  Both run the
    paged engine so the only variable is the prefill strategy.
    """
    import jax
    import numpy as np

    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    engine = ServingEngine(model, params, n_lanes=n_lanes, max_len=max_len,
                           cache="paged", page_size=page_size,
                           prefill_chunk=prefill_chunk)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    long_prompt = (rng.integers(0, cfg.vocab_size,
                                size=long_len) % cfg.vocab_size).tolist()
    engine.submit(Request(rid=0, prompt=long_prompt,
                          max_new_tokens=max_new))
    for rid in range(1, n_short + 1):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(4, 8))).tolist()
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=max_new))
    finished = engine.run(max_steps=(n_short + 1) * (max_new + 6)
                          + long_len)
    wall = time.time() - t0
    s = engine.metrics.summary()
    by_rid = {r.rid: r for r in finished}
    short_ttfts = sorted(r.first_token_t - r.submit_t
                         for r in finished if r.rid != 0)
    short_p50 = short_ttfts[len(short_ttfts) // 2] if short_ttfts else None
    long_ttft = (by_rid[0].first_token_t - by_rid[0].submit_t
                 if 0 in by_rid else None)
    return {
        "arch": arch, "cache": "paged", "workload": "mixed",
        "prefill_chunk": prefill_chunk, "n_lanes": n_lanes,
        "requests": n_short + 1, "finished": len(finished),
        "decode_steps": engine.steps,
        "prefill_chunks": engine.prefill_chunks,
        "generated_tokens": s["generated_tokens"],
        "tokens_per_s": s["generated_tokens"] / wall if wall else 0.0,
        "ttft_p50_s": s["ttft_s"]["p50"], "ttft_p99_s": s["ttft_s"]["p99"],
        "ttft_short_p50_s": short_p50, "ttft_long_s": long_ttft,
        "itl_p50_s": s["itl_s"]["p50"], "itl_p99_s": s["itl_s"]["p99"],
        "preemptions": s["preemptions"],
        "wall_s": wall,
    }


def bench_long_context(arch: str, long_len: int, n_requests: int,
                       max_new: int, page_size: int, repeats: int,
                       seed: int = 0) -> list:
    """Long-context decode: split-KV committed vs forced-sequential.

    One lane, long prompts: every decode step walks a long page table,
    so p50 ITL tracks the serial KV walk the Flash-Decoding split axis
    is meant to shorten.  Two cells per length, both through the serve
    harness with run-time tuning on: ``num_splits`` forced to 1 (the
    legacy sequential kernel spelling — only the ``block_k`` ladder is
    tuned) and the autotuned split ladder ({1, 2, 4}, committed per
    length bucket).  Each mode pays one cold run that tunes into its own
    workdir, then the timed repeats warm-load the committed DB
    (``measurements == 0`` — steady state, no tuning overhead in the
    rows) and the min-ITL repeat is kept.  The committed cell reports
    ``greedy_agreement`` against the forced-1 outputs — the split-KV
    combine is exact up to fp32 rounding and greedy argmax must not
    flip — and ``compare.py`` gates agreement at 100% plus committed
    p50 ITL <= forced-1 (the tuner may *pick* the sequential spelling,
    it must never commit something slower).
    """
    import tempfile

    from repro import at
    from repro.launch.serve import serve

    rows = []
    seq_outputs: dict = {}
    with tempfile.TemporaryDirectory() as tmp:
        for mode in (1, "auto"):
            kw = {"autotune": True}
            if mode != "auto":
                kw["num_splits"] = mode
            workdir = os.path.join(tmp, f"ns_{mode}")
            os.makedirs(workdir, exist_ok=True)
            best = None
            for rep in range(1 + max(1, repeats)):
                at.clear_published()
                try:
                    report = serve(
                        arch=arch, cache="paged", page_size=page_size,
                        n_requests=n_requests, n_lanes=1,
                        max_len=long_len + max_new + 4,
                        prompt_len=long_len, max_new=max_new,
                        workdir=workdir, seed=seed, **kw)
                finally:
                    at.clear_published()
                if rep == 0:
                    continue  # cold run pays the tuning measurements
                if best is None or \
                        (report["p50_itl_s"] if report["p50_itl_s"]
                         is not None else float("inf")) < \
                        (best["p50_itl_s"] if best["p50_itl_s"]
                         is not None else float("inf")):
                    best = report
            outputs = best["outputs"]
            if mode != "auto":
                seq_outputs = outputs
                agreement = 1.0
            else:
                match = total = 0
                for rid, ref in seq_outputs.items():
                    got = outputs.get(rid, [])
                    total += max(len(ref), len(got))
                    match += sum(a == b for a, b in zip(ref, got))
                agreement = match / total if total else 1.0
            committed = best.get("committed_buckets") or {}
            rows.append({
                "arch": arch, "cache": "paged", "workload": "long_context",
                "long_len": long_len, "num_splits": mode, "n_lanes": 1,
                "committed_splits": {
                    str(b): (pp or {}).get("num_splits")
                    for b, pp in committed.items()},
                "requests": n_requests, "finished": best["finished"],
                "decode_steps": best["decode_steps"],
                "generated_tokens": best["generated_tokens"],
                "tokens_per_s": best["tokens_per_s"],
                "ttft_p50_s": best["p50_ttft_s"],
                "ttft_p99_s": best["p99_ttft_s"],
                "itl_p50_s": best["p50_itl_s"],
                "itl_p99_s": best["p99_itl_s"],
                "warm_measurements": (best["autotune"] or {}).get(
                    "measurements"),
                "greedy_agreement": agreement,
                "preemptions": best["preemptions"],
                "wall_s": best["wall_s"],
            })
    return rows


def bench_spec(arch: str, spec_k: int, n_requests: int, n_lanes: int,
               max_len: int, max_new: int, page_size: int,
               seed: int = 0) -> dict:
    """Greedy speculative decoding on the paged engine (one cell per k).

    The draft is self-speculative: ``draft_config(depth_frac=0.75)`` of a
    4-layer variant of the reduced config, parameters sliced from the
    target's own leading layers with shared embed/head (see module
    docstring for why the depth is bumped for these cells).
    """
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = dataclasses.replace(get_arch(arch).reduced(), n_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    draft_model = model.draft_model(depth_frac=0.75)
    draft_params = model.slice_draft_params(params, draft_model)
    engine = ServingEngine(model, params, n_lanes=n_lanes, max_len=max_len,
                           cache="paged", page_size=page_size,
                           draft_model=draft_model,
                           draft_params=draft_params, spec_k=spec_k)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for rid in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(4, 12))).tolist()
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=max_new))
    finished = engine.run(max_steps=n_requests * (max_new + 6))
    wall = time.time() - t0
    s = engine.metrics.summary()
    spec = engine.spec_stats()
    return {
        "arch": arch, "cache": "paged", "workload": "spec",
        "spec_k": spec_k, "n_layers": cfg.n_layers,
        "draft_layers": draft_model.cfg.n_layers, "n_lanes": n_lanes,
        "requests": n_requests, "finished": len(finished),
        "decode_steps": engine.steps, "spec_ticks": spec["spec_ticks"],
        "drafted_tokens": spec["drafted_tokens"],
        "accepted_tokens": spec["accepted_tokens"],
        "accept_rate": spec["accept_rate"],
        "generated_tokens": s["generated_tokens"],
        "tokens_per_s": s["generated_tokens"] / wall if wall else 0.0,
        "tokens_per_step": (s["generated_tokens"] / engine.steps
                            if engine.steps else 0.0),
        "ttft_p50_s": s["ttft_s"]["p50"], "ttft_p99_s": s["ttft_s"]["p99"],
        "itl_p50_s": s["itl_s"]["p50"], "itl_p99_s": s["itl_s"]["p99"],
        "preemptions": s["preemptions"],
        "cache_stats": engine.kv.stats(),
        "wall_s": wall,
    }


def bench_shared_prefix(arch: str, prefix_cache: bool, n_requests: int,
                        n_lanes: int, max_len: int, max_new: int,
                        page_size: int, prefix_len: int = 32,
                        prefill_chunk: int = 8, seed: int = 0) -> dict:
    """Shared-system-prompt workload on the chunked paged engine.

    Every request = one common ``prefix_len``-token prompt + a short
    unique tail.  With ``prefix_cache=True`` the first admission
    publishes the prefix's pages into the hash index and later
    admissions seed them (refcounted / copy-on-write), starting chunked
    prefill at the first uncached token — their TTFT drops by the
    skipped chunks.  Outputs are bit-identical either way; only the
    work changes.
    """
    import jax
    import numpy as np

    from repro.configs import get_arch
    from repro.models import build_model
    from repro.serving import Request, ServingEngine

    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    engine = ServingEngine(model, params, n_lanes=n_lanes, max_len=max_len,
                           cache="paged", page_size=page_size,
                           prefill_chunk=prefill_chunk,
                           prefix_cache=prefix_cache)
    rng = np.random.default_rng(seed)
    t0 = time.time()
    prefix = rng.integers(0, cfg.vocab_size, size=prefix_len).tolist()
    for rid in range(n_requests):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, 7))).tolist()
        engine.submit(Request(rid=rid, prompt=prefix + tail,
                              max_new_tokens=max_new))
    finished = engine.run(
        max_steps=n_requests * (max_new + 6 + prefix_len))
    wall = time.time() - t0
    s = engine.metrics.summary()
    pc = s["prefix_cache"]
    kvp = engine.kv.stats().get("prefix", {})
    return {
        "arch": arch, "cache": "paged", "workload": "shared_prefix",
        "prefix_cache": prefix_cache, "prefix_len": prefix_len,
        "prefill_chunk": prefill_chunk, "n_lanes": n_lanes,
        "requests": n_requests, "finished": len(finished),
        "decode_steps": engine.steps,
        "prefill_chunks": engine.prefill_chunks,
        "prefix_hit_rate": pc["hit_rate"],
        "prefix_hit_tokens": pc["hit_tokens"],
        "pages_saved": kvp.get("pages_saved", 0),
        "cow_copies": kvp.get("cow_copies", 0),
        "generated_tokens": s["generated_tokens"],
        "tokens_per_s": s["generated_tokens"] / wall if wall else 0.0,
        "ttft_p50_s": s["ttft_s"]["p50"], "ttft_p99_s": s["ttft_s"]["p99"],
        "itl_p50_s": s["itl_s"]["p50"], "itl_p99_s": s["itl_s"]["p99"],
        "preemptions": s["preemptions"],
        "wall_s": wall,
    }


def bench_gateway(arch: str, n_requests: int, rate: float, turns: int,
                  max_new: int, queue_limit: int, seed: int = 0) -> dict:
    """Closed-loop load through the HTTP/SSE gateway (``loadgen.py``
    in-process: real localhost TCP, Poisson session arrivals, multi-turn
    prefix re-hits, bounded admission queue).  The cell's headline
    numbers are the two ``compare.py`` gates serving quality on:
    ``slo_attainment`` and ``goodput_tok_s`` (tokens/s from within-SLO
    requests only).  Latencies here are *client-side* — queueing,
    network and compute together."""
    import asyncio

    try:
        from .loadgen import run_in_process
    except ImportError:
        sys.path.insert(0, os.path.dirname(__file__))
        from loadgen import run_in_process

    report = asyncio.run(run_in_process(
        arch=arch, queue_limit=queue_limit, seed=seed,
        n_requests=n_requests, rate=rate, turns=turns,
        out_mean=max(2.0, max_new * 0.75), max_out=max_new))
    server = report["server"]
    return {
        "arch": arch, "cache": "paged", "workload": "gateway",
        "prefill_chunk": 16, "prefix_cache": True,
        "requests": report["requests"], "finished": report["completed"],
        "sessions": report["sessions"], "turns": report["turns"],
        "arrival_rate_per_s": report["arrival_rate_per_s"],
        "queue_limit": queue_limit,
        "rejected_429": report["rejected_429"],
        "generated_tokens": report["generated_tokens"],
        "tokens_per_s": report["tokens_per_s"],
        "goodput_tok_s": report["goodput_tok_s"],
        "slo_attainment": report["slo_attainment"],
        "slo_ok": report["slo_ok"],
        "slo_ttft_s": report["slo_ttft_s"],
        "slo_itl_s": report["slo_itl_s"],
        "queue_wait_p50_s": report["queue_wait_s"]["p50"],
        "queue_wait_p99_s": report["queue_wait_s"]["p99"],
        "ttft_p50_s": report["ttft_s"]["p50"],
        "ttft_p99_s": report["ttft_s"]["p99"],
        "itl_p50_s": report["itl_s"]["p50"],
        "itl_p99_s": report["itl_s"]["p99"],
        "prefix_hit_tokens": report["prefix_hit_tokens"],
        "ticks": server["ticks"],
        "overlapped_ticks": server["overlapped_ticks"],
        "preemptions": server["preemptions"],
        "wall_s": report["wall_s"],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--archs", nargs="+", default=list(DEFAULT_ARCHS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--lanes", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--timeslice", type=int, default=4)
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="chunk size for the mixed-workload chunked cells")
    ap.add_argument("--long-len", type=int, default=48,
                    help="long-prompt length in the mixed workload")
    ap.add_argument("--long-lens", type=int, nargs="+", default=[32, 64],
                    help="prompt-length sweep for the long_context "
                         "split-KV cells (committed vs forced-1, one "
                         "cell pair per length)")
    ap.add_argument("--spec-ks", type=int, nargs="+", default=[1, 4],
                    help="draft lengths for the speculative cells "
                         "(one cell per k)")
    ap.add_argument("--mesh", default="2x2",
                    help="device mesh 'RxC' (data x model) for the "
                         "tensor-parallel cells; forced host devices, "
                         "run in a subprocess")
    ap.add_argument("--prefix-len", type=int, default=64,
                    help="shared system-prompt length for the "
                         "shared_prefix cells (cache off vs on)")
    ap.add_argument("--gateway-requests", type=int, default=24,
                    help="total requests the gateway load cell drives "
                         "through the HTTP/SSE front-end")
    ap.add_argument("--gateway-rate", type=float, default=50.0,
                    help="Poisson session-arrival rate for the gateway "
                         "cell (sessions/s)")
    ap.add_argument("--gateway-turns", type=int, default=2,
                    help="closed-loop turns per gateway session")
    ap.add_argument("--gateway-queue-limit", type=int, default=32,
                    help="gateway admission-queue bound (429 beyond)")
    ap.add_argument("--repeats", type=int, default=2,
                    help="run each cell N times, keep the best run: the "
                         "first repeat pays jit compile time, later ones "
                         "reuse the in-process cache, so best-of-N "
                         "measures steady-state serving rather than "
                         "compile jitter")
    args = ap.parse_args()

    def fmt(x, spec):
        return format(x, spec) if x is not None else "n/a"

    def best_of(run):
        rows = [run() for _ in range(max(1, args.repeats))]
        return max(rows, key=lambda r: r["tokens_per_s"])

    results = []
    for arch in args.archs:
        for cache in ("dense", "paged"):
            ts = args.timeslice if cache == "paged" else None
            row = best_of(lambda: bench_one(
                arch, cache, args.requests, args.lanes, args.max_len,
                args.max_new, args.page_size, ts))
            results.append(row)
            print(f"[bench_serving] {arch:14s} {cache:6s} uniform  "
                  f"{row['tokens_per_s']:8.1f} tok/s  "
                  f"ttft p50 {fmt(row['ttft_p50_s'], '.3f')}s "
                  f"p99 {fmt(row['ttft_p99_s'], '.3f')}s  "
                  f"itl p50 {fmt(row['itl_p50_s'], '.4f')}s  "
                  f"preempt {row['preemptions']}")
        # kv precision at fixed bytes: the int8 cell buys ~3x the pages
        # for the same budget and must track the fp cell's greedy
        # outputs (compare.py gates capacity ratio and agreement)
        kv_outputs: dict = {}
        for kvd in ("fp", "int8"):
            runs = [bench_kv_dtype(arch, kvd, args.requests, args.lanes,
                                   args.max_len, args.max_new,
                                   args.page_size, args.timeslice)
                    for _ in range(max(1, args.repeats))]
            row, outs = max(runs, key=lambda t: t[0]["tokens_per_s"])
            kv_outputs[kvd] = outs
            if kvd == "fp":
                row["greedy_agreement"] = 1.0
            else:
                match = total = 0
                for rid, ref in kv_outputs["fp"].items():
                    got = outs.get(rid, [])
                    total += max(len(ref), len(got))
                    match += sum(a == b for a, b in zip(ref, got))
                row["greedy_agreement"] = match / total if total else 1.0
            results.append(row)
            print(f"[bench_serving] {arch:14s} paged  kv/{kvd:9s} "
                  f"{row['tokens_per_s']:8.1f} tok/s  "
                  f"cap {row['capacity_tokens']} tok "
                  f"({row['n_pages']} pages, "
                  f"{row['kv_bytes_per_token']:.0f} B/tok)  "
                  f"swaps {row['swap_outs']}  "
                  f"agree {row['greedy_agreement']:.0%}")
        # tensor-parallel mesh: the sharded engine must reproduce the
        # unsharded greedy outputs exactly (compare.py gates agreement).
        # One run, not best-of: the subprocess pays jit compile twice
        # (reference + mesh) and the cell's signal is agreement, not
        # steady-state throughput.
        row = bench_mesh(arch, args.mesh, args.requests, args.lanes,
                         args.max_len, args.max_new, args.page_size,
                         args.prefill_chunk)
        results.append(row)
        print(f"[bench_serving] {arch:14s} paged  mesh/{args.mesh:8s} "
              f"{row['tokens_per_s']:8.1f} tok/s  "
              f"{row['n_devices']} devices  "
              f"agree {row['greedy_agreement']:.0%}")
        # mixed long/short workload: monolithic vs chunked prefill.  The
        # mixed max_len must fit long_len + max_new headroom.
        mixed_len = max(args.max_len, args.long_len + args.max_new + 2)
        for chunk in (None, args.prefill_chunk):
            row = best_of(lambda: bench_mixed(
                arch, chunk, args.requests, args.lanes, mixed_len,
                args.max_new, args.page_size, long_len=args.long_len))
            results.append(row)
            mode = f"chunk={chunk}" if chunk else "monolithic"
            print(f"[bench_serving] {arch:14s} paged  mixed/{mode:11s} "
                  f"short-ttft p50 {fmt(row['ttft_short_p50_s'], '.3f')}s  "
                  f"long ttft {fmt(row['ttft_long_s'], '.3f')}s  "
                  f"{row['tokens_per_s']:6.1f} tok/s")
        # long-context decode: split-KV committed (autotuned ladder) vs
        # forced num_splits=1 (the sequential spelling), 1 lane so p50
        # ITL is a single decode step's critical path.  compare.py gates
        # agreement at 100% and committed ITL <= forced-1 per length.
        for ll in args.long_lens:
            for row in bench_long_context(arch, ll, args.requests,
                                          args.max_new, args.page_size,
                                          args.repeats):
                results.append(row)
                mode = f"ns={row['num_splits']}"
                print(f"[bench_serving] {arch:14s} paged  "
                      f"long/{ll:<4d}{mode:8s} "
                      f"itl p50 {fmt(row['itl_p50_s'], '.4f')}s  "
                      f"{row['tokens_per_s']:6.1f} tok/s  "
                      f"agree {row['greedy_agreement']:.0%}")
        # shared system prompt: prefix cache off vs on.  The cached cell
        # must show a TTFT drop (admissions skip the prefix's chunks)
        # and a nonzero hit rate (gated by compare.py).  One lane, so
        # request 0 publishes the prefix before request 1 admits — the
        # hit rate is structural ((n-1)/n), not a concurrency accident.
        # The off/on repeats INTERLEAVE (off, on, off, on, ...) so both
        # variants sample the same machine-noise windows, and each cell
        # keeps its min-TTFT repeat — the pairing + floor is what lets a
        # 10-25% structural saving survive CPU-proxy scheduler jitter.
        sp_len = max(args.max_len, args.prefix_len + args.max_new + 10)
        sp_rows: dict = {False: [], True: []}
        for _ in range(max(2, args.repeats + 1)):
            for cached in (False, True):
                sp_rows[cached].append(bench_shared_prefix(
                    arch, cached, args.requests, 1, sp_len,
                    args.max_new, args.page_size,
                    prefix_len=args.prefix_len,
                    prefill_chunk=args.prefill_chunk))
        for cached in (False, True):
            # a repeat that finished nothing has no TTFT: sort it last,
            # never let it masquerade as the fastest run
            row = min(sp_rows[cached],
                      key=lambda r: (r["ttft_p50_s"]
                                     if r["ttft_p50_s"] is not None
                                     else float("inf")))
            results.append(row)
            mode = "cache=on " if cached else "cache=off"
            print(f"[bench_serving] {arch:14s} paged  prefix/{mode:11s} "
                  f"ttft p50 {fmt(row['ttft_p50_s'], '.3f')}s  "
                  f"hit {row['prefix_hit_rate']:.0%}  "
                  f"{row['pages_saved']} pages saved  "
                  f"{row['tokens_per_s']:6.1f} tok/s")
        # gateway load: Poisson arrivals through the HTTP/SSE front-end.
        # best-of keeps the max-goodput repeat (first pays jit compile)
        g_rows = [bench_gateway(arch, args.gateway_requests,
                                args.gateway_rate, args.gateway_turns,
                                args.max_new, args.gateway_queue_limit)
                  for _ in range(max(1, args.repeats))]
        row = max(g_rows, key=lambda r: r["goodput_tok_s"])
        results.append(row)
        print(f"[bench_serving] {arch:14s} paged  gateway      "
              f"{row['goodput_tok_s']:8.1f} good tok/s  "
              f"SLO {row['slo_attainment']:.0%}  "
              f"queue p99 {fmt(row['queue_wait_p99_s'], '.3f')}s  "
              f"{row['rejected_429']} bounced  "
              f"{row['overlapped_ticks']}/{row['ticks']} overlapped")
        # speculative decode: tokens/s + accept rate per draft length k
        for k in args.spec_ks:
            row = best_of(lambda: bench_spec(
                arch, k, args.requests, args.lanes, args.max_len,
                args.max_new, args.page_size))
            results.append(row)
            print(f"[bench_serving] {arch:14s} paged  spec/k={k:<2d}     "
                  f"{row['tokens_per_s']:8.1f} tok/s  "
                  f"accept {row['accepted_tokens']}/{row['drafted_tokens']} "
                  f"({row['accept_rate']:.0%})  "
                  f"{row['tokens_per_step']:.2f} tok/step")

    # the run shape is stamped into the payload so compare.py can refuse
    # to diff two benchmarks that measured different workloads
    config = {"archs": list(args.archs), "requests": args.requests,
              "lanes": args.lanes, "max_len": args.max_len,
              "max_new": args.max_new, "page_size": args.page_size,
              "timeslice": args.timeslice,
              "kv_dtypes": ["fp", "int8"],
              "mesh": args.mesh,
              "prefill_chunk": args.prefill_chunk,
              "long_len": args.long_len, "spec_ks": list(args.spec_ks),
              "long_lens": list(args.long_lens),
              "split_modes": [1, "auto"],
              "prefix_len": args.prefix_len,
              "gateway_requests": args.gateway_requests,
              "gateway_rate": args.gateway_rate,
              "gateway_turns": args.gateway_turns,
              "gateway_queue_limit": args.gateway_queue_limit,
              "repeats": args.repeats}
    payload = {"benchmark": "serving", "config": config, "results": results}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"[bench_serving] wrote {args.out} ({len(results)} cells)")


if __name__ == "__main__":
    main()
