"""Paper-claim benchmarks — one function per paper table/figure.

The paper is a language spec, so its 'tables' are semantic claims:

* Sample 10 search counts (the §6.4.2 worked example, all four cases);
* Sample 8's 8 loop-split/fusion variants (codegen wall time + numeric
  identity);
* Sample 1 fitting quality (inferred vs true optimum over cost-curve
  families);
* parameter-file round-trip throughput (the install/static persistence
  layer);
* AD-HOC vs brute-force search-cost scaling (Fig. 3's motivation).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (ATRegion, CountingExecutor, Fitting, SearchPlan,
                        Varied, predicted_count)
from repro.core import paramfile
from repro.core.codegen import OATCodeGen


def bench_sample10_counts() -> list[tuple[str, float, str]]:
    from tests.fdm_sample import fdm_stress  # noqa: F401  (layout only)

    def build(outer, inner):
        root = ATRegion("static", "variable", "ABlockRoutine",
                        fn=lambda **kw: None, varied=Varied("BL", 1, 16),
                        search=outer)
        root.add_child(ATRegion("static", "unroll", "Kernel1",
                                fn=lambda **kw: None,
                                varied=Varied(("i", "j"), 1, 32),
                                search=inner))
        root.add_child(ATRegion("static", "unroll", "Kernel2",
                                fn=lambda **kw: None,
                                varied=Varied(("l", "m"), 1, 32),
                                search=inner))
        return root

    rows = []
    cases = [("brute-force", "brute-force", 16_777_216),
             ("ad-hoc", "ad-hoc", 144),
             ("brute-force", "ad-hoc", 144),
             ("ad-hoc", "brute-force", 2_064)]
    for outer, inner, want in cases:
        t0 = time.perf_counter()
        got = predicted_count(build(outer, inner))
        us = (time.perf_counter() - t0) * 1e6
        ok = "OK" if got == want else f"MISMATCH(got {got})"
        rows.append((f"sample10[{outer[:5]}/{inner[:5]}]", us,
                     f"count={got} {ok}"))
    return rows


def bench_sample8_codegen() -> list[tuple[str, float, str]]:
    import tests.fdm_sample as tc
    gen = OATCodeGen("/tmp/bench_oat")
    t0 = time.perf_counter()
    variants = gen.generate(tc.fdm_stress)["FDMStress"]
    gen_us = (time.perf_counter() - t0) * 1e6
    arrs, state = tc._fdm_inputs(n=8)
    base = variants[0].fn(8, 8, 8, **arrs,
                          **{k: v.copy() for k, v in state.items()}, DT=0.1)
    times = []
    all_match = True
    for v in variants:
        st = {k: vv.copy() for k, vv in state.items()}
        t0 = time.perf_counter()
        out = v.fn(8, 8, 8, **arrs, **st, DT=0.1)
        times.append((time.perf_counter() - t0) * 1e6)
        for b, o in zip(base, out):
            all_match &= bool(np.allclose(b, o, rtol=1e-12))
    return [("sample8_codegen", gen_us,
             f"variants={len(variants)} identical={all_match}"),
            ("sample8_variant_exec", float(np.mean(times)),
             f"mean over {len(variants)} variants (n=8^3)")]


def bench_fitting_quality() -> list[tuple[str, float, str]]:
    """Inferred-vs-true optimum over 100 random unroll-like cost curves
    (a/u + b*u), comparing the paper's fitting methods at 7/16 measured
    points.  LS-5 (Sample 1's choice) extrapolates poorly on 1/u tails;
    the d-spline (Tanaka Lab method the paper also offers) is the robust
    pick — the bench quantifies why the *choice of CDF* is itself a PP."""
    rng = np.random.default_rng(0)
    xs = [1, 2, 3, 4, 5, 8, 16]
    methods = {
        "ls5": Fitting.least_squares(5, sampled=xs),
        "ls2": Fitting.least_squares(2, sampled=xs),
        "dspline": Fitting.dspline(sampled=xs),
        "auto": Fitting("auto", sampled=xs),
    }
    n = 100
    curves = [(rng.uniform(3, 30), rng.uniform(0.05, 0.5))
              for _ in range(n)]
    rows = []
    for name, fitting in methods.items():
        hits = 0
        t0 = time.perf_counter()
        for a, b in curves:
            cost = lambda u: a / u + b * u
            r = ATRegion("install", "unroll", "U", fn=lambda **kw: None,
                         varied=Varied(("u",), 1, 16), fitting=fitting)
            res = SearchPlan(r).run(lambda asg: cost(asg["U_U"]))
            true = min(range(1, 17), key=cost)
            hits += abs(res.best["U_U"] - true) <= 1
        us = (time.perf_counter() - t0) * 1e6 / n
        rows.append((f"fitting_{name}_7samples", us,
                     f"within-1 hit rate={hits}/{n} "
                     f"(7/16 points measured)"))
    return rows


def bench_paramfile_roundtrip() -> list[tuple[str, float, str]]:
    nodes = []
    for r in range(20):
        rec = paramfile.Node(f"Region{r}")
        for s in (1024, 2048, 3072, 4096):
            g = paramfile.Node("OAT_PROBSIZE", s)
            for p in range(8):
                g.set(f"Region{r}_P{p}", (s // 1024) * p)
            rec.children.append(g)
        nodes.append(rec)
    t0 = time.perf_counter()
    n = 50
    for _ in range(n):
        text = paramfile.dumps(nodes)
        back = paramfile.loads(text)
    us = (time.perf_counter() - t0) * 1e6 / n
    ok = back == nodes
    return [("paramfile_roundtrip", us,
             f"20 regions x 4 BP points x 8 PPs, identity={ok}")]


def bench_search_scaling() -> list[tuple[str, float, str]]:
    """AD-HOC (sum N) vs brute-force (prod N) actual evaluation counts."""
    rows = []
    for n_axes, n in ((2, 8), (3, 8), (4, 6)):
        names = tuple(f"p{i}" for i in range(n_axes))
        region_bf = ATRegion("static", "variable", "S",
                             fn=lambda **kw: None,
                             varied=Varied(names, 1, n))
        region_ah = ATRegion("static", "variable", "S",
                             fn=lambda **kw: None,
                             varied=Varied(names, 1, n), search="ad-hoc")
        cost = lambda asg: sum((v - 2) ** 2 for v in asg.values())
        exb, exa = CountingExecutor(cost), CountingExecutor(cost)
        t0 = time.perf_counter()
        SearchPlan(region_bf).run(exb)
        SearchPlan(region_ah).run(exa)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"search_scaling_{n_axes}x{n}", us,
                     f"brute={exb.count} adhoc={exa.count} "
                     f"ratio={exb.count / exa.count:.1f}x"))
    return rows


ALL = [bench_sample10_counts, bench_sample8_codegen, bench_fitting_quality,
       bench_paramfile_roundtrip, bench_search_scaling]
