"""Serving example: continuous batching + run-time auto-tuning.

    PYTHONPATH=src python examples/serve_lm.py

Serves a reduced yi-6b with batched requests through the lane engine, and
demonstrates the paper's run-time (dynamic) AT: the first calls per
sequence-length bucket measure decode variants, then commit a winner
(OAT_DynPerfThis semantics for every call after).
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import ATContext
from repro.launch.serve import serve
from repro.tuning import DecodeAutoTuner


def main():
    out = serve(arch="yi-6b", n_requests=6, n_lanes=3, max_len=80,
                max_new=8)
    print(f"served {out['finished']}/{out['requests']} requests: "
          f"{out['generated_tokens']} tokens, "
          f"{out['tokens_per_s']:.1f} tok/s (CPU-proxy), "
          f"ttft {out['mean_ttft_s']:.2f}s")

    # run-time AT on the decode path (paper Samples 6/7)
    ctx = ATContext(tempfile.mkdtemp(prefix="serve_at_"))
    ctx.phase_ran["install"] = ctx.phase_ran["static"] = True
    timings = {256: 3e-3, 512: 1e-3, 1024: 2e-3}    # simulated kernel costs

    def make_decode(block_k):
        def fn():
            import time
            time.sleep(timings[block_k])
            return {"block_k": block_k}
        return fn

    tuner = DecodeAutoTuner(ctx, make_decode, buckets=(512,),
                            block_ks=(256, 512, 1024))
    for i in range(5):
        out = tuner.decode(300)
        state = ctx.dynamic_state["DecodeBucket_512"]
        phase = "tuning" if state.committed is None or i < 3 else "committed"
        print(f"call {i}: block_k={out['block_k']} [{phase}]")
    assert tuner.committed()[512] == 1     # 512 is fastest
    print("run-time AT committed block_k=512 (fastest) — OK")


if __name__ == "__main__":
    main()
