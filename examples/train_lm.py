"""End-to-end training driver: train a ~100M-param dense LM for a few
hundred steps with checkpointing + deterministic resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Uses a ~100M-parameter llama-style config (the deepseek-7b family scaled
to 12 layers x 768) on CPU.  Demonstrates: data pipeline, AdamW + cosine
schedule, grad clipping, microbatching, async checkpoints, exact resume.
"""
import argparse
import dataclasses
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_arch
from repro.configs.base import ArchConfig
from repro.data import DataConfig, batch_for_step
from repro.launch.train import train
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    # ~100M params: 12L x 768 dense llama-style
    import repro.configs.registry as reg
    cfg100m = dataclasses.replace(
        get_arch("deepseek-7b"), name="deepseek-100m", n_layers=12,
        d_model=768, n_heads=12, n_kv_heads=12, d_head=64, d_ff=2048,
        vocab_size=32000)
    reg.ARCHS[cfg100m.name] = cfg100m
    n = cfg100m.param_count()
    print(f"training {cfg100m.name}: {n/1e6:.0f}M params, "
          f"{args.steps} steps, seq {args.seq_len}, batch {args.batch}")

    ckpt = tempfile.mkdtemp(prefix="lm100m_ckpt_")
    out = train(arch=cfg100m.name, steps=args.steps, reduced=False,
                seq_len=args.seq_len, batch=args.batch,
                ckpt_dir=ckpt, ckpt_every=50, num_microbatches=2,
                remat="full", log_every=10)
    print(f"\nfinal loss {out['final_loss']:.4f} "
          f"(start {out['losses'][0]:.4f}) in {out['wall_s']:.0f}s; "
          f"checkpoints in {ckpt}")
    assert out["final_loss"] < out["losses"][0]


if __name__ == "__main__":
    main()
