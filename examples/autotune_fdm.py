"""Paper §5 Sample 8 end-to-end: auto-tune the ppOpen-APPL/FDM stress
kernel's 8 loop-split/fusion variants — at BOTH levels of the stack,
entirely through the ``repro.at`` session API.

    PYTHONPATH=src python examples/autotune_fdm.py

Level 1 (the paper, literally): the annotated Python loop nest is expanded
by ``AutoTuner.preprocess`` into the 8 candidates, each wall-clock
measured through a named executor backend, and the winner committed
through an install-time select region (then persisted in the session's
record store).

Level 2 (the TPU adaptation): the same kernel as a Pallas pallas_call with
the fused-vs-split trade-off (SplitPointCopyDef == rematerialisation of the
QG plane) plus VMEM block-shape PPs, validated against the jnp oracle.
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tests"))

import jax.numpy as jnp
import numpy as np

import repro.at as at
from repro.kernels import ref
from repro.kernels.fdm_stress import fdm_stress


def main():
    from fdm_sample import _fdm_inputs, fdm_stress as fdm_loops

    workdir = tempfile.mkdtemp(prefix="oat_fdm_")
    tuner = at.AutoTuner(workdir, executor="fdm-wallclock")
    tuner.set_bps(numprocs=1, start=8, end=8, dist=8)

    regions = tuner.preprocess(fdm_loops)
    region = regions["FDMStress"]
    print(f"Sample 8 candidates ({len(region.subregions)}):")
    for i, sub in enumerate(region.subregions, 1):
        print(f"  #{i} {sub.name}")
    assert len(region.subregions) == 8

    n = 10
    arrs, state = _fdm_inputs(n=n)

    @at.executors.register("fdm-wallclock")
    def fdm_executor(region, bp_env):
        def measure(asg):
            idx = asg["FDMStress_SELECT"]
            st = {k: v.copy() for k, v in state.items()}
            t0 = time.perf_counter()
            region.subregions[idx].fn(n, n, n, **arrs, **st, DT=0.1)
            return time.perf_counter() - t0
        return measure

    tuner.run("install", ["FDMStress"])
    best = int(tuner.best("FDMStress")["FDMStress_SELECT"])
    print(f"install-time winner: #{best + 1} "
          f"({region.subregions[best].name})")
    print(f"({tuner.executor_calls} variants measured; winner persisted in "
          f"{at.ATRecordStore(workdir).path})\n")

    # ---- level 2: the Pallas kernel variants --------------------------
    rng = np.random.default_rng(0)
    nx = ny = nz = 16
    arrays = dict(
        lam=jnp.asarray(rng.normal(size=(nx, ny, nz)), jnp.float32),
        rig=jnp.asarray(rng.uniform(0.5, 2.0, size=(nx, ny, nz)),
                        jnp.float32),
        q=jnp.asarray(rng.normal(size=(nx, ny, nz)), jnp.float32),
        absx=jnp.asarray(rng.normal(size=nx), jnp.float32),
        absy=jnp.asarray(rng.normal(size=ny), jnp.float32),
        absz=jnp.asarray(rng.normal(size=nz), jnp.float32),
        **{k: jnp.asarray(rng.normal(size=(nx, ny, nz)), jnp.float32)
           for k in ("dxvx", "dyvy", "dzvz", "dxvy", "dyvx", "dxvz",
                     "dzvx", "dyvz", "dzvy")})
    st = {k: jnp.asarray(rng.normal(size=(nx, ny, nz)), jnp.float32)
          for k in ("sxx", "syy", "szz", "sxy", "sxz", "syz")}
    want = ref.fdm_stress_ref(arrays, st, 0.1)
    print("Pallas variants (interpret mode, vs jnp oracle):")
    for variant in ("fused", "split"):
        out = fdm_stress(arrays, st, 0.1, variant=variant, bx=8, by=8,
                         bz=8, interpret=True)
        err = max(float(jnp.abs(out[k] - want[k]).max()) for k in want)
        print(f"  {variant:6s} max_err={err:.2e} "
              f"(QG {'computed once' if variant == 'fused' else 'recomputed — SplitPointCopyDef/remat'})")
    print("\nOK — paper Sample 8 reproduced at loop-nest AND kernel level.")


if __name__ == "__main__":
    main()
