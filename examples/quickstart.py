"""Quickstart: the complete ppOpen-AT flow on a real kernel in ~60 lines,
entirely through the ``repro.at`` session API.

    PYTHONPATH=src python examples/quickstart.py

1. annotate a matmul with #OAT$ directives (paper Sample 1/4 style);
2. ``AutoTuner.preprocess`` expands it into unrolled variants under ./OAT/;
3. ``AutoTuner.run("install")`` searches the (i, j) unroll space with a
   custom executor registered by name in ``at.executors``;
4. the tuned variant runs, numerically identical to the baseline;
5. a SECOND session pointed at the same workdir warm-loads the optimum
   from the persistent record store — zero measurements.
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import repro.at as at


def matmul_kernel(N, A, B, C):
    #OAT$ install unroll region start
    #OAT$ name MyMatMul
    #OAT$ varied (i, j) from 1 to 4
    #OAT$ search AD-HOC
    for i in range(N):
        for j in range(N):
            for k in range(N):
                A[i, j] = A[i, j] + B[i, k] * C[k, j]
    #OAT$ install unroll region end
    return A


n = 16
rng = np.random.default_rng(0)
b, c = rng.normal(size=(n, n)), rng.normal(size=(n, n))


@at.executors.register("unrolled-matmul")
def measure_variant(region, bp_env):
    """Wall-clock one unrolled variant on a 16x16 matmul."""
    def measure(asg):
        variant = region.fn(i=asg["MyMatMul_I"], j=asg["MyMatMul_J"])
        a = np.zeros((n, n))
        t0 = time.perf_counter()
        variant(n, a, b, c)
        return time.perf_counter() - t0
    return measure


def make_session(workdir):
    tuner = at.AutoTuner(workdir, executor="unrolled-matmul")
    tuner.set_bps(numprocs=1, start=16, end=16, dist=16)
    regions = tuner.preprocess(matmul_kernel)
    return tuner, regions


def main():
    workdir = tempfile.mkdtemp(prefix="oat_quickstart_")
    tuner, regions = make_session(workdir)
    print(f"registered regions: {list(regions)}")
    print(f"generated code: {workdir}/OAT/OAT_matmul_kernel.py")

    tuner.run("install", ["MyMatMul"])
    best = tuner.best("MyMatMul")
    besti, bestj = best["MyMatMul_I"], best["MyMatMul_J"]
    print(f"tuned unroll factors: i={besti} j={bestj} "
          f"(searched {tuner.executor_calls} variants, AD-HOC)")

    a = np.zeros((n, n))
    regions["MyMatMul"].fn(i=besti, j=bestj)(n, a, b, c)
    np.testing.assert_allclose(a, b @ c, rtol=1e-10)
    print("tuned variant matches numpy matmul — OK")
    print(open(os.path.join(workdir, "OAT_InstallParam.dat")).read())

    # the tuning database makes the result durable: a fresh session on the
    # same workdir loads the optimum without re-timing anything
    tuner2, _ = make_session(workdir)
    tuner2.run("install", ["MyMatMul"])
    assert tuner2.executor_calls == 0, "warm path must not re-measure"
    assert tuner2.best("MyMatMul") == best
    print(f"second session: warm-loaded i={besti} j={bestj} from "
          f"{at.ATRecordStore(workdir).path} with 0 measurements — OK")


if __name__ == "__main__":
    main()
