"""Quickstart: the complete ppOpen-AT flow on a real kernel in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. annotate a matmul with #OAT$ directives (paper Sample 1/4 style);
2. OATCodeGen expands it into unrolled variants under ./OAT/;
3. OAT_ATexec(OAT_INSTALL) searches the (i, j) unroll space;
4. the tuned variant runs, numerically identical to the baseline.
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import ATContext, OAT_INSTALL
from repro.core.dsl import preprocess


def matmul_kernel(N, A, B, C):
    #OAT$ install unroll region start
    #OAT$ name MyMatMul
    #OAT$ varied (i, j) from 1 to 4
    #OAT$ search AD-HOC
    for i in range(N):
        for j in range(N):
            for k in range(N):
                A[i, j] = A[i, j] + B[i, k] * C[k, j]
    #OAT$ install unroll region end
    return A


def main():
    workdir = tempfile.mkdtemp(prefix="oat_quickstart_")
    ctx = ATContext(workdir)
    for k, v in (("OAT_NUMPROCS", 1), ("OAT_STARTTUNESIZE", 16),
                 ("OAT_ENDTUNESIZE", 16), ("OAT_SAMPDIST", 16)):
        ctx.store.set_bp(k, v)

    regions = preprocess(matmul_kernel, ctx, workdir)
    print(f"registered regions: {list(regions)}")
    print(f"generated code: {workdir}/OAT/OAT_matmul_kernel.py")

    # measure real wall-clock of each unrolled variant on a 16x16 matmul
    rng = np.random.default_rng(0)
    n = 16
    b, c = rng.normal(size=(n, n)), rng.normal(size=(n, n))
    region = regions["MyMatMul"]

    import time

    def executor(region, bp_env):
        def measure(asg):
            fi, fj = asg["MyMatMul_I"], asg["MyMatMul_J"]
            variant = region.fn(i=fi, j=fj)
            a = np.zeros((n, n))
            t0 = time.perf_counter()
            variant(n, a, b, c)
            return time.perf_counter() - t0
        return measure

    ctx._executor_factory = executor
    ctx.OAT_ATexec(OAT_INSTALL, ["MyMatMul"])
    besti = ctx.store.entry("MyMatMul_I").value
    bestj = ctx.store.entry("MyMatMul_J").value
    print(f"tuned unroll factors: i={besti} j={bestj} "
          f"(searched {ctx.search_log['MyMatMul']} variants, AD-HOC)")

    a = np.zeros((n, n))
    region.fn(i=besti, j=bestj)(n, a, b, c)
    np.testing.assert_allclose(a, b @ c, rtol=1e-10)
    print("tuned variant matches numpy matmul — OK")
    print(open(os.path.join(workdir, "OAT_InstallParam.dat")).read())


if __name__ == "__main__":
    main()
